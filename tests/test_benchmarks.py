"""Benchmark helpers: convergence_episode robustness."""
import numpy as np

from benchmarks.scheduling import convergence_episode


def test_convergence_empty_and_singleton():
    assert convergence_episode([]) == 0
    assert convergence_episode([5.0]) == 0


def test_convergence_short_lists_no_degenerate_slice():
    # fewer than 3 episodes: plateau window must clamp to the list length
    assert convergence_episode([5.0, 5.0]) == 0
    assert convergence_episode([10.0, 5.0]) in (0, 1)


def test_convergence_constant_curve():
    assert convergence_episode([2.0] * 10) == 0
    # all-zero plateau must not divide by zero
    assert convergence_episode([0.0] * 5) == 0


def test_convergence_detects_plateau_start():
    curve = [10.0, 8.0, 6.0] + [5.0] * 12
    i = convergence_episode(curve)
    assert i == 3
    # noisy plateau still converges near the knee
    rng = np.random.default_rng(0)
    noisy = [10.0, 8.0, 6.0] + list(5.0 + 0.01 * rng.standard_normal(12))
    assert convergence_episode(noisy) <= 4


def test_convergence_never_out_of_range():
    for n in range(8):
        curve = list(np.linspace(10.0, 1.0, n))
        i = convergence_episode(curve)
        assert 0 <= i <= max(n - 1, 0)
