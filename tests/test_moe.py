"""MoE: local reference semantics + sharded-path equivalence (the
multi-device check runs in a subprocess so the main test session keeps the
single real CPU device)."""
import dataclasses
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import MoEConfig, get_config, reduced
from repro.models import moe as moelib

KEY = jax.random.key(0)


def _cfg(E=4, K=2, cf=2.0, d=64, f=96):
    base = reduced(get_config("dbrx-132b"))
    return dataclasses.replace(
        base, d_model=d, d_ff=f, head_dim=d // base.num_heads,
        moe=MoEConfig(num_experts=E, top_k=K, capacity_factor=cf))


def test_output_shape_and_aux():
    cfg = _cfg()
    p = moelib.init_moe(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model))
    out, aux = moelib.apply_moe(p, cfg, x)
    assert out.shape == x.shape
    assert float(aux) > 0  # load-balance loss is positive


def test_topk_only_active_experts_contribute():
    """Zeroing the weights of all experts outside a token's top-k must not
    change that token's output."""
    cfg = _cfg(E=4, K=1, cf=4.0)
    p = moelib.init_moe(KEY, cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (1, 8, cfg.d_model))
    out1, _ = moelib.apply_moe(p, cfg, x)
    # find each token's chosen expert, then zero a never-chosen expert
    xf = x.reshape(-1, cfg.d_model)
    probs = jax.nn.softmax(xf @ p["router"], axis=-1)
    chosen = set(np.asarray(jnp.argmax(probs, -1)).tolist())
    unused = [e for e in range(4) if e not in chosen]
    if not unused:
        pytest.skip("all experts used by this sample")
    e = unused[0]
    p2 = jax.tree_util.tree_map(lambda a: a, p)
    for k in ("we_up", "we_down", "we_gate"):
        if k in p2:
            p2[k] = p2[k].at[e].set(0.0)
    out2, _ = moelib.apply_moe(p2, cfg, x)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               atol=1e-6)


def test_capacity_drops_tokens():
    """With capacity factor ~0, outputs must (mostly) vanish."""
    cfg = _cfg(E=4, K=2, cf=1e-6)
    p = moelib.init_moe(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 32, cfg.d_model))
    out, _ = moelib.apply_moe(p, cfg, x)
    cfg_big = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=2.0))
    out_big, _ = moelib.apply_moe(p, cfg_big, x)
    # capacity C=1 keeps at most 4 tokens' worth of outputs
    dropped = float((jnp.abs(out).sum(-1) == 0).mean())
    kept_big = float((jnp.abs(out_big).sum(-1) > 0).mean())
    assert dropped > 0.5
    assert kept_big > 0.9


def test_moe_gradients_flow():
    cfg = _cfg()
    p = moelib.init_moe(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (1, 16, cfg.d_model))

    def loss(p):
        out, aux = moelib.apply_moe(p, cfg, x)
        return (out ** 2).mean() + aux

    g = jax.grad(loss)(p)
    for name in ("we_up", "we_down", "router"):
        assert float(jnp.abs(g[name]).max()) > 0, name


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, jax, jax.numpy as jnp
    from repro.configs import get_config, reduced, MoEConfig
    from repro.launch import sharding as shlib
    from repro.models import moe as moelib
    cfg = dataclasses.replace(
        reduced(get_config("dbrx-132b")), d_ff=96,
        moe=MoEConfig(num_experts=4, top_k=2, capacity_factor=2.0))
    p = moelib.init_moe(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model))
    ref, _ = moelib.apply_moe(p, cfg, x)
    worst = 0.0
    for shape in [(2, 4), (1, 8), (4, 2), (8, 1)]:
        mesh = jax.make_mesh(shape, ("data", "model"))
        ctx = shlib.ShardingContext(mesh)
        with mesh:
            with shlib.use(ctx):
                out, _ = jax.jit(
                    lambda p, x: moelib.apply_moe(p, cfg, x))(p, x)
        worst = max(worst, float(jnp.max(jnp.abs(out - ref))))
    print("WORST", worst)
    assert worst < 1e-4, worst
""")


@pytest.mark.slow
def test_sharded_path_matches_local_multidevice():
    import os
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env = dict(os.environ, PYTHONPATH=src)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "WORST" in r.stdout
