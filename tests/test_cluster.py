"""repro.cluster: Request accounting, continuous batching, and the
Scheduler interface against both the simulator and live engines."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster import (EdgeCluster, PolicyScheduler, Request,
                           evaluate_scheduler, make_scheduler,
                           poisson_trace, summarize)
from repro.configs import get_config, reduced
from repro.core.agents import AgentConfig
from repro.core.diffusion import DiffusionPolicyConfig
from repro.core.env import EnvParams
from repro.core.trainer import train_method
from repro.models.transformer import init_params
from repro.serving.engine import ServeEngine

KEY = jax.random.key(0)
ENV = EnvParams(num_bs=2, num_slots=3, max_tasks=3)
ACFG = AgentConfig(train_after=10, replay_capacity=60, batch_size=16,
                   diffusion=DiffusionPolicyConfig(num_steps=2))


def _engine(num_layers=2, kv_slots=2, max_len=40, seed=0, **kw):
    cfg = dataclasses.replace(reduced(get_config("qwen2-1.5b")),
                              num_layers=num_layers)
    params = init_params(jax.random.key(seed), cfg)
    return ServeEngine(cfg, params, max_len=max_len, kv_slots=kv_slots,
                       **kw)


def _prompt(engine, n=1, S=8, seed=0):
    return jax.random.randint(jax.random.key(seed), (n, S), 0,
                              engine.cfg.vocab_size)


# ---------------------------------------------------------------------------
# request-latency accounting
# ---------------------------------------------------------------------------


def test_burst_latency_accounting_monotone_and_sums():
    """A multi-request burst on one engine: per-request timestamps must be
    non-negative, monotone, and decompose the total delay exactly (covers
    the old queue_s/pending_seconds path and continuous batching)."""
    engine = _engine(kv_slots=2)
    prompts = _prompt(engine, 1, 8)
    reqs = [Request(rid=r, prompt=prompts, max_new_tokens=3 + r)
            for r in range(5)]            # burst > kv_slots -> real queueing
    for r in reqs:
        engine.admit(r)
    done = engine.run_to_completion()
    assert len(done) == len(reqs)
    for r in reqs:
        assert r.done
        assert r.t_enqueue <= r.t_prefill_start <= r.t_prefill_end \
            <= r.t_finish
        assert r.queue_s >= 0 and r.prefill_s >= 0 and r.decode_s >= 0
        assert abs((r.queue_s + r.prefill_s + r.decode_s) - r.total_s) \
            < 1e-9
        assert len(r.tokens) == r.max_new_tokens
    # with a 2-slot pool and 5 requests, someone must have queued behind
    # an occupied slot
    assert max(r.queue_s for r in reqs) > 0


def test_continuous_batching_late_request_overtakes():
    """Slot reuse: a late short request joins the decode batch mid-flight
    and finishes before an earlier long request completes."""
    engine = _engine(kv_slots=2)
    prompts = _prompt(engine, 2, 8)
    long = Request(rid=0, prompt=prompts[0:1], max_new_tokens=16)
    engine.admit(long)
    for _ in range(3):
        engine.step()                      # long is mid-decode
    short = Request(rid=1, prompt=prompts[1:2], max_new_tokens=2)
    engine.admit(short)
    engine.run_to_completion()
    assert short.done and long.done
    assert short.t_finish < long.t_finish
    assert short.t_enqueue > long.t_prefill_end   # genuinely late arrival
    assert len(long.tokens) == 16 and len(short.tokens) == 2


def test_slot_reuse_after_free():
    """Freed slots are refilled from the queue; pool stays fixed-size.

    Pinned to the dense slot engine: under the paged pool both requests
    fit in flight at once and the second never waits (that behavior is
    covered in test_paged_kv)."""
    engine = _engine(kv_slots=1, paged=False)
    prompts = _prompt(engine, 1, 8)
    a = Request(rid=0, prompt=prompts, max_new_tokens=2)
    b = Request(rid=1, prompt=prompts, max_new_tokens=2)
    engine.admit(a)
    engine.admit(b)
    engine.run_to_completion()
    assert a.done and b.done
    assert b.t_prefill_start >= a.t_finish - 1e-6   # b waited for the slot
    # identical prompt + greedy decoding -> identical tokens
    np.testing.assert_array_equal(np.stack(a.tokens), np.stack(b.tokens))


def test_pool_decode_matches_sequential_reference():
    """Tokens produced inside the shared slot pool must match a dedicated
    single-request run (per-slot caches are truly independent)."""
    engine = _engine(kv_slots=2)
    prompts = _prompt(engine, 2, 8, seed=3)
    solo = engine.generate(prompts[0:1], 5)
    engine.reset()
    # now serve the same prompt while another request shares the batch
    r0 = Request(rid=0, prompt=prompts[0:1], max_new_tokens=5)
    r1 = Request(rid=1, prompt=prompts[1:2], max_new_tokens=5)
    engine.admit(r0)
    engine.admit(r1)
    engine.run_to_completion()
    np.testing.assert_array_equal(
        np.stack([np.asarray(t[0:1]) for t in solo.tokens]),
        np.stack(r0.tokens))


def test_pending_tokens_tracks_backlog():
    engine = _engine(kv_slots=1)
    prompts = _prompt(engine, 1, 8)
    engine.admit(Request(rid=0, prompt=prompts, max_new_tokens=4))
    engine.admit(Request(rid=1, prompt=prompts, max_new_tokens=6))
    assert engine.pending_tokens == 10
    engine.step()
    assert 0 < engine.pending_tokens < 10
    engine.run_to_completion()
    assert engine.pending_tokens == 0
    assert engine.pending_seconds >= 0.0


# ---------------------------------------------------------------------------
# scheduler interface: same object drives sim and live cluster
# ---------------------------------------------------------------------------


def _schedulers():
    _, states = train_method("lad-ts", ENV, ACFG, episodes=1, key=KEY)
    return {
        "lad-ts": PolicyScheduler("lad-ts", ACFG, states, num_engines=2,
                                  n_max=ENV.max_tasks),
        "jsq": make_scheduler("jsq", 2),
        "round-robin": make_scheduler("round-robin", 2),
        "random": make_scheduler("random", 2),
        "local": make_scheduler("local", 2),
    }


def test_schedulers_drive_simulator_and_live_cluster():
    scheds = _schedulers()
    # --- simulator backend
    for name, s in scheds.items():
        r = evaluate_scheduler(s, ENV, episodes=1, key=jax.random.key(1))
        assert r["count"] > 0, name
        assert r["mean_s"] > 0 and r["p95_s"] >= r["mean_s"] * 0.5, name
    # --- live backend, >= 2 engines, same scheduler objects
    engines = [_engine(num_layers=2, seed=0), _engine(num_layers=4, seed=1)]
    vocab = engines[0].cfg.vocab_size
    for name, s in scheds.items():
        for e in engines:
            e.reset()
        cluster = EdgeCluster(engines, s, seed=2)
        trace = poisson_trace(4, rate=50.0, prompt_len=8, max_new_tokens=3,
                              vocab_size=vocab, num_origins=2, seed=5)
        done = cluster.run(trace)
        stats = summarize(done)
        assert stats["count"] == 4, name
        assert stats["p95_s"] >= stats["mean_s"] > 0, name
        for r in done:
            assert abs((r.queue_s + r.prefill_s + r.decode_s) - r.total_s) \
                < 1e-9


def test_round_robin_cycles_engines():
    s = make_scheduler("round-robin", 3)
    carry = s.init_carry()
    picks = []
    for i in range(6):
        a, carry = s.select_one(carry, jnp.zeros((5,)), 0, 0,
                                jax.random.key(i))
        picks.append(a)
    assert picks == [0, 1, 2, 0, 1, 2]


def test_jsq_picks_emptiest_engine():
    s = make_scheduler("jsq", 3)
    obs = jnp.asarray([1.0, 1.0, 0.9, 0.1, 0.5])   # queues = [.9, .1, .5]
    a, _ = s.select_one(s.init_carry(), obs, 0, 0, jax.random.key(0))
    assert a == 1


def test_local_only_keeps_origin():
    s = make_scheduler("local", 4)
    for origin in range(4):
        a, _ = s.select_one(s.init_carry(), jnp.zeros((6,)), origin, 0,
                            jax.random.key(0))
        assert a == origin


def test_scheduler_select_batch_shapes():
    for name in ("jsq", "round-robin", "random", "local"):
        s = make_scheduler(name, ENV.num_bs)
        a, _ = s.select(s.init_carry(),
                        jnp.zeros((ENV.num_bs, ENV.state_dim)), 0,
                        jax.random.key(0))
        assert a.shape == (ENV.num_bs,)
        assert a.dtype == jnp.int32
        assert bool(((a >= 0) & (a < ENV.num_bs)).all())


def test_unknown_scheduler_raises():
    with pytest.raises(ValueError):
        make_scheduler("nope", 2)
