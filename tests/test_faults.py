"""repro.faults: injector determinism, engine crash/recovery invariants,
cluster retry/watchdog semantics, and the fault-enabled simulator."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster import (EdgeCluster, Request, evaluate_scheduler,
                           make_scheduler, poisson_trace, summarize)
from repro.configs import get_config, reduced
from repro.core.env import EnvParams
from repro.core import env as envlib
from repro.faults import (FaultEvent, FaultInjector, FaultParams, FaultSpec,
                          Health, RetryPolicy, single_crash)
from repro.models.transformer import init_params
from repro.serving.engine import ServeEngine
from repro.workload import INTERACTIVE, STANDARD, BEST_EFFORT
from repro.workload.queueing import EDFQueue

KEY = jax.random.key(0)


def _engine(arch="qwen2-1.5b", num_layers=2, kv_slots=2, max_len=40,
            seed=0, **kw):
    cfg = dataclasses.replace(reduced(get_config(arch)),
                              num_layers=num_layers)
    params = init_params(jax.random.key(seed), cfg)
    return ServeEngine(cfg, params, max_len=max_len, kv_slots=kv_slots,
                       **kw)


def _prompt(engine, n=1, S=8, seed=0):
    return jax.random.randint(jax.random.key(seed), (n, S), 0,
                              engine.cfg.vocab_size)


def _req(rid, prompt, tokens=4, qos=None, deadline_s=None, arrival_s=0.0):
    return Request(rid=rid, prompt=prompt, max_new_tokens=tokens,
                   qos=qos, deadline_s=deadline_s, arrival_s=arrival_s)


# ---------------------------------------------------------------------------
# injector: determinism, auto-recovery, replay
# ---------------------------------------------------------------------------


def test_fault_schedule_deterministic_per_seed():
    spec = FaultSpec(crashes=2, stalls=1, slowdowns=1)
    a = FaultInjector.from_spec(spec, 4, horizon_s=10.0, seed=7)
    b = FaultInjector.from_spec(spec, 4, horizon_s=10.0, seed=7)
    c = FaultInjector.from_spec(spec, 4, horizon_s=10.0, seed=8)
    assert a.describe() == b.describe()
    assert a.describe() != c.describe()
    # every finite-duration crash/slowdown got a matching recover event
    kinds = [e["kind"] for e in a.describe()]
    assert kinds.count("recover") == 3          # 2 crashes + 1 slowdown


def test_injector_fires_each_event_once_and_replays():
    inj = single_crash(engine=1, t_s=1.0, downtime_s=2.0, num_engines=2)
    assert [e.kind for e in inj.due(0.5)] == []
    assert [e.kind for e in inj.due(1.5)] == ["crash"]
    assert [e.kind for e in inj.due(1.5)] == []       # exactly once
    assert [e.kind for e in inj.due(10.0)] == ["recover"]
    assert inj.exhausted
    inj.reset()
    assert not inj.exhausted
    assert [e.kind for e in inj.due(10.0)] == ["crash", "recover"]


def test_injector_rejects_bad_events():
    with pytest.raises(ValueError):
        FaultEvent(t_s=1.0, engine=0, kind="meltdown")
    with pytest.raises(ValueError):
        FaultInjector([FaultEvent(t_s=0.0, engine=5, kind="crash")],
                      num_engines=2)


def test_retry_policy_backoff_and_watchdog():
    rp = RetryPolicy(max_attempts=4, backoff_base_s=0.1, backoff_factor=2.0,
                     deadline_grace=2.0, best_effort_timeout_s=5.0)
    assert rp.backoff_s(1) == pytest.approx(0.1)
    assert rp.backoff_s(3) == pytest.approx(0.4)
    # deadline-carrying request: hopeless past grace * budget
    r = Request(rid=0, prompt=None, max_new_tokens=1, arrival_s=0.0,
                deadline_s=1.0)
    r.t_arrival = 100.0
    assert not rp.hopeless(r, 101.9)
    assert rp.hopeless(r, 102.1)
    # best-effort: flat timeout
    b = Request(rid=1, prompt=None, max_new_tokens=1)
    b.t_arrival = 100.0
    assert not rp.hopeless(b, 104.0)
    assert rp.hopeless(b, 105.1)


# ---------------------------------------------------------------------------
# engine health: crash reclaims KV, degraded modes, shedding
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("paged", [True, False])
def test_crash_mid_prefill_and_mid_decode_reclaims_kv(paged):
    """kv_leak must return to 0 after a crash at ANY lifecycle point,
    for both the paged page pool and the dense slot pool."""
    kw = {"prefill_chunk": 4} if paged else {}
    e = _engine(paged=paged, kv_slots=2, max_len=40, **kw)
    prompts = _prompt(e, 2, 12)
    reqs = [_req(i, prompts[i:i + 1], tokens=8) for i in range(2)]
    for r in reqs:
        e.admit(r)
    e.step()                      # paged: mid-prefill; dense: mid-decode
    assert e.kv_leak > 0          # KV actually held before the crash
    orphans = e.fail("test crash mid-prefill/decode")
    assert e.kv_leak == 0
    assert e.health is Health.DOWN
    assert sorted(r.rid for r in orphans) == [0, 1]

    # crash mid-decode after recovery
    e.recover()
    for r in orphans:
        r.reset_for_retry()
        e.admit(r)
    for _ in range(4):
        e.step()                  # prefill done, several decode rounds
    assert e.kv_leak > 0
    orphans = e.fail("test crash mid-decode")
    assert e.kv_leak == 0
    assert len(orphans) == 2
    # full recovery: the SAME requests complete cleanly afterwards
    e.recover()
    for r in orphans:
        r.reset_for_retry()
        e.admit(r)
    done = e.run_to_completion()
    assert sorted(r.rid for r in done) == [0, 1]
    assert all(len(r.tokens) == r.max_new_tokens for r in done)
    assert e.kv_leak == 0


def test_crash_mid_prefill_shared_prefix_reclaims_only_unshared():
    """A crash mid-prefill on a lane that shares a cached prefix must
    drop ONLY that lane's references: cached pages stay resident (the
    cache's own refs), refcounts return to exactly one-per-entry
    (kv_leak == 0), and the retried request hits the cache again."""
    e = _engine(paged=True, kv_slots=2, max_len=64, page_size=8,
                prefill_chunk=8)
    prompt = _prompt(e, 1, 24, seed=42)
    done = e.generate(prompt, 2)               # seed the prefix cache
    assert len(done.tokens) == 2
    cached = e.prefix_cached_pages
    assert cached == 3                         # 24 tokens / page 8
    r = _req(1, prompt, tokens=4)
    e.admit(r)
    e.step()                                   # mid-prefill, prefix shared
    assert r.prefix_tokens == 23               # 2 full pages + 7 COW
    assert e.kv_leak > 0                       # lane refs actually held
    orphans = e.fail("crash mid-prefill on shared prefix")
    assert orphans == [r]
    assert e.kv_leak == 0                      # only unshared refs dropped
    assert e.prefix_cached_pages == cached     # cache intact through crash
    assert e._pool.total_refs == cached        # exactly 1 ref per entry
    e.recover()
    r.reset_for_retry()
    saved0 = e.prefill_tokens_saved
    e.admit(r)
    out = e.run_to_completion()
    assert [x.rid for x in out] == [1]
    assert len(r.tokens) == 4
    assert e.prefill_tokens_saved == saved0 + 23   # retry hit the cache
    assert e.kv_leak == 0


def test_down_engine_rejects_admission_and_degraded_modes():
    e = _engine(paged=False, kv_slots=1)
    e.fail("boom")
    with pytest.raises(RuntimeError, match="DOWN"):
        e.admit(_req(0, _prompt(e), tokens=2))
    assert e.availability == 0.0 and not e.available
    e.recover()
    assert e.health is Health.HEALTHY and e.availability == 1.0
    # stall: frozen, then self-heals
    clock = [0.0]
    e._clock = lambda: clock[0]
    e.degrade(stall_s=5.0)
    assert e.availability == 0.5 and e.available
    e.admit(_req(1, _prompt(e), tokens=1))
    assert e.step() == []          # frozen
    clock[0] = 6.0
    done = e.run_to_completion()
    assert [r.rid for r in done] == [1]
    assert e.health is Health.HEALTHY       # stall self-healed


def test_degrade_down_engine_raises():
    e = _engine(paged=False, kv_slots=1)
    e.fail("boom")
    with pytest.raises(RuntimeError):
        e.degrade(stall_s=1.0)


# ---------------------------------------------------------------------------
# EDF re-entry and queue shedding
# ---------------------------------------------------------------------------


def test_orphans_reenter_edf_queue_in_priority_deadline_order():
    """Requests orphaned by a crash re-enter another engine's EDF queue
    and drain in (priority desc, deadline asc) order regardless of the
    order the crash emitted them."""
    a = _engine(paged=False, kv_slots=1, seed=0)
    b = _engine(paged=False, kv_slots=1, seed=1)
    p = _prompt(a)
    reqs = [
        _req(0, p, qos=BEST_EFFORT),
        _req(1, p, qos=INTERACTIVE, deadline_s=2.0),
        _req(2, p, qos=STANDARD, deadline_s=6.0),
        _req(3, p, qos=INTERACTIVE, deadline_s=1.0),
    ]
    for r in reqs:
        a.admit(r)
    orphans = a.fail("crash")
    assert len(orphans) == 4
    for r in orphans:
        r.reset_for_retry()
        b.admit(r)
    order = []
    while b._queue:
        order.append(b._queue.popleft().rid)
    # interactive (prio 4) by deadline, then standard, then batch
    assert order == [3, 1, 2, 0]


def test_edf_drain_preserves_surviving_order():
    q = EDFQueue()
    p = None
    reqs = [_req(i, p, qos=[INTERACTIVE, STANDARD, BEST_EFFORT][i % 3],
                 deadline_s=float(10 - i)) for i in range(6)]
    for r in reqs:
        q.append(r)
    shed = q.drain(lambda r: r.qos is BEST_EFFORT)
    assert sorted(r.rid for r in shed) == [2, 5]
    survivors = []
    while q:
        survivors.append(q.popleft())
    keys = [(-r.priority, r.deadline_s) for r in survivors]
    assert keys == sorted(keys)


# ---------------------------------------------------------------------------
# cluster: crash recovery, retries, watchdog, quarantine
# ---------------------------------------------------------------------------


def _cluster_pair(**kw):
    engines = [_engine(paged=False, kv_slots=2, seed=i) for i in range(2)]
    sched = make_scheduler("jsq", 2)
    return engines, EdgeCluster(engines, sched, **kw)


def test_cluster_crash_recovery_no_duplicate_completions():
    """Mid-trace crash: all requests complete exactly once, token streams
    are whole, KV accounting returns to zero, attempts are bounded."""
    engines, cluster = _cluster_pair(
        faults=single_crash(engine=0, t_s=0.02, downtime_s=0.2,
                            num_engines=2),
        retry=RetryPolicy())
    vocab = engines[0].cfg.vocab_size
    trace = poisson_trace(10, rate=100.0, prompt_len=8, max_new_tokens=4,
                          vocab_size=vocab, num_origins=2, seed=5)
    done = cluster.run(trace)
    assert len(done) == 10                       # each request exactly once
    assert len({r.rid for r in done}) == 10      # no duplicates
    st = summarize(done)
    assert st["completion_rate"] == 1.0
    assert st["completed"] == 10 and st["failed"] == 0
    for r in done:
        assert r.status == "ok"
        assert 1 <= r.attempts <= cluster.retry.max_attempts
        assert len(r.tokens) == r.max_new_tokens     # no torn streams
    assert all(e.kv_leak == 0 for e in engines)
    assert cluster.fault_stats["injected"] == 2      # crash + recover
    if cluster.fault_stats["orphaned"]:
        assert st["retries"] >= 1
        assert cluster.fault_stats["orphan_recovery_s"]


def test_cluster_submit_raises_on_total_outage():
    engines, cluster = _cluster_pair()
    for e in engines:
        e.fail("both down")
    with pytest.raises(RuntimeError, match="all 2 engines are DOWN"):
        cluster.submit(_req(0, _prompt(engines[0]), tokens=2))


def test_cluster_quarantines_throwing_engine(monkeypatch):
    """An exception escaping one engine's step() marks it DOWN and
    re-offloads its requests instead of unwinding the closed loop."""
    engines, cluster = _cluster_pair(retry=RetryPolicy())
    vocab = engines[0].cfg.vocab_size

    def explode():
        raise RuntimeError("synthetic engine fault")

    # the overlapped cluster path enters through dispatch(); the serial
    # fallback through step() — explode both
    monkeypatch.setattr(engines[0], "step", explode)
    monkeypatch.setattr(engines[0], "dispatch", explode)
    trace = poisson_trace(6, rate=200.0, prompt_len=8, max_new_tokens=3,
                          vocab_size=vocab, num_origins=2, seed=2)
    done = cluster.run(trace)
    assert engines[0].health is Health.DOWN
    assert "quarantined" in engines[0].fail_reason
    assert cluster.fault_stats["quarantined"] == 1
    st = summarize(done)
    assert st["completion_rate"] == 1.0          # engine 1 absorbed all
    assert len(done) == 6


def test_watchdog_abandons_hopeless_never_counts_delay():
    """A request whose deadline is hopeless is abandoned (status stamped,
    no t_finish) and never enters the delay percentiles."""
    engines, cluster = _cluster_pair(retry=RetryPolicy(
        best_effort_timeout_s=0.001, deadline_grace=1.0))
    e = engines[0]
    # park a best-effort request in the retry queue in the past
    r = _req(0, _prompt(e), tokens=2)
    r.t_arrival = cluster._clock() - 10.0        # long overdue
    cluster._park(r, cluster._clock() - 1.0)
    done = cluster.step()
    assert [x.status for x in done] == ["abandoned"]
    assert r.t_finish is None
    st = summarize([r])
    assert st["abandoned"] == 1 and st["count"] == 0
    assert st["p99_s"] == 0.0                    # nothing entered delays
    assert st["completion_rate"] == 1.0          # shed, not failed


def test_retries_exhausted_marks_failed():
    engines, cluster = _cluster_pair(retry=RetryPolicy(max_attempts=2))
    r = _req(0, _prompt(engines[0]), tokens=2)
    r.t_arrival = cluster._clock()
    r.attempts = 2                               # already placed twice
    out = cluster._requeue(r, cluster._clock())
    assert out == [r] and r.status == "failed"
    assert "retries exhausted" in r.fail_reason
    assert cluster.fault_stats["failed"] == 1


def test_fault_free_cluster_has_no_watchdog_side_effects():
    """Without faults= / retry= the watchdog must never shed — the
    fault-free cluster behaves exactly like the pre-fault one."""
    engines, cluster = _cluster_pair()
    assert not cluster._watchdog
    r = _req(0, _prompt(engines[0]), tokens=2, deadline_s=1e-9)
    r.t_arrival = cluster._clock() - 100.0       # hopeless by any watchdog
    engines[0].admit(r)
    assert cluster._shed_hopeless(cluster._clock()) == []
    assert r.status == "pending"


# ---------------------------------------------------------------------------
# fault-enabled simulator
# ---------------------------------------------------------------------------


def test_env_legacy_parity_with_empty_fault_config():
    """fault=None and FaultParams(p_down=0) produce bit-identical delay
    statistics — the availability extension is provably inert when off."""
    p0 = EnvParams(num_bs=3, num_slots=5, max_tasks=4)
    pf = dataclasses.replace(p0, fault=FaultParams(p_down=0.0, p_up=1.0))
    r0 = evaluate_scheduler(make_scheduler("jsq", 3), p0, 2, KEY)
    rf = evaluate_scheduler(make_scheduler("jsq", 3), pf, 2, KEY)
    for k in ("mean_s", "p50_s", "p95_s", "p99_s", "count"):
        assert r0[k] == rf[k], k
    assert rf["wrong_choice_rate"] == 0.0
    assert rf["completion_rate"] == 1.0


def test_env_fault_state_dim_and_observe_guard():
    p = EnvParams(num_bs=3, fault=FaultParams())
    assert p.state_dim == 2 + 3 + 3
    assert envlib.state_scale(p).shape == (p.state_dim,)
    qs = envlib.init_queues(p)
    d = jnp.ones((3,))
    with pytest.raises(ValueError, match="availability"):
        envlib.observe(p, qs, d, d)
    s = envlib.observe(p, qs, d, d, avail=jnp.array([1.0, 0.0, 1.0]))
    assert s.shape == (3, p.state_dim)
    np.testing.assert_array_equal(np.asarray(s[:, -3:]),
                                  np.tile([1.0, 0.0, 1.0], (3, 1)))


def test_step_avail_transitions_and_mask_actions():
    fp = FaultParams(p_down=0.5, p_up=0.5)
    avail = jnp.array([1.0, 1.0, 0.0, 0.0])
    u = jnp.array([0.4, 0.6, 0.4, 0.6])      # < p triggers a transition
    out = np.asarray(envlib.step_avail(fp, avail, u))
    np.testing.assert_array_equal(out, [0.0, 1.0, 1.0, 0.0])
    # masking: picks on DOWN engines remap to least-loaded UP engine
    load = jnp.array([5.0, 1.0, 0.0])
    actions = jnp.array([2, 0, 1], jnp.int32)
    masked, wrong = envlib.mask_actions(jnp.array([1.0, 1.0, 0.0]), load,
                                        actions)
    np.testing.assert_array_equal(np.asarray(masked), [1, 0, 1])
    np.testing.assert_array_equal(np.asarray(wrong), [True, False, False])
    # all-down: picks stand, nothing is penalised
    masked, wrong = envlib.mask_actions(jnp.zeros(3), load, actions)
    np.testing.assert_array_equal(np.asarray(masked), np.asarray(actions))
    assert not np.asarray(wrong).any()


def test_sim_down_engines_dont_drain():
    p = EnvParams(num_bs=2, fault=FaultParams())
    ep = envlib.sample_episode(KEY, p)
    qs = envlib.QueueState(q_prev=jnp.array([4.0, 4.0]),
                           q_bef=jnp.zeros(2))
    out = envlib.end_slot(p, ep, qs, avail=jnp.array([1.0, 0.0]))
    q = np.asarray(out.q_prev)
    assert q[0] < 4.0                      # healthy engine drained
    assert q[1] == 4.0                     # DOWN engine carried over


def test_fault_schedule_reproducible_in_sim():
    """Same seed -> bit-identical fault-enabled episode results."""
    p = EnvParams(num_bs=3, num_slots=6, max_tasks=4,
                  fault=FaultParams(p_down=0.3, p_up=0.5))
    a = evaluate_scheduler(make_scheduler("round-robin", 3), p, 2, KEY)
    b = evaluate_scheduler(make_scheduler("round-robin", 3), p, 2, KEY)
    assert a["mean_s"] == b["mean_s"]
    assert a["wrong_choice_rate"] == b["wrong_choice_rate"]
    assert a["wrong_choice_rate"] > 0.0    # faults actually fired


def test_failure_aware_scheduler_masks_down_engines():
    p = EnvParams(num_bs=3, num_slots=8, max_tasks=5,
                  fault=FaultParams(p_down=0.3, p_up=0.3, penalty_s=5.0))
    fa = evaluate_scheduler(make_scheduler("failure-aware", 3), p, 2, KEY)
    rr = evaluate_scheduler(make_scheduler("round-robin", 3), p, 2, KEY)
    assert fa["wrong_choice_rate"] == 0.0
    assert rr["wrong_choice_rate"] > 0.0
    assert fa["mean_s"] < rr["mean_s"]


def test_live_observation_appends_availability_and_nan_guards():
    engines, _ = _cluster_pair()
    sched = make_scheduler("failure-aware", 2)
    cluster = EdgeCluster(engines, sched)
    assert cluster.fault_obs and not cluster.qos_obs
    engines[1].fail("test")
    row = np.asarray(cluster.observe(_req(0, _prompt(engines[0]))))
    assert row.shape == (cluster.obs_dim,)
    np.testing.assert_array_equal(row[-2:], [1.0, 0.0])
    assert np.isfinite(row).all()


def test_state_dim_mismatch_message_mentions_faults():
    engines = [_engine(paged=False, kv_slots=1, seed=i) for i in range(2)]
    sched = make_scheduler("failure-aware", 2)
    with pytest.raises(ValueError, match="state_dim"):
        EdgeCluster(engines, sched, fault_obs=False)
