import os
import sys

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benchmarks must see
# the 1 real CPU device.  Only the dry-run (repro.launch.dryrun) forces 512
# placeholder devices, and multi-device sharding tests spawn a subprocess
# with their own flag (tests/test_sharding_multidevice.py).
_HERE = os.path.dirname(__file__)
sys.path.insert(0, os.path.join(_HERE, "..", "src"))
sys.path.insert(0, os.path.join(_HERE, ".."))   # benchmarks.* imports
sys.path.insert(0, _HERE)                        # _property shim
