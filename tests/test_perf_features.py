"""Beyond-paper perf features: int8 KV cache, hoisted MoE layout,
weights-stationary serving MoE, dp-even microbatching."""
import dataclasses
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import MoEConfig, get_config, reduced
from repro.models import forward, init_params
from repro.models import moe as moelib


def test_int8_cache_close_to_bf16():
    base = reduced(get_config("qwen2-1.5b"))
    cfg8 = dataclasses.replace(base, kv_cache_dtype="int8")
    key = jax.random.key(1)
    params = init_params(key, base)
    B, S = 2, 16
    toks = jax.random.randint(key, (B, S + 1), 0, base.vocab_size)
    full = forward(params, base, {"tokens": toks},
                   mode="prefill")["last_logits"]
    st = forward(params, cfg8, {"tokens": toks[:, :S]}, mode="prefill",
                 max_len=S + 8)["states"]
    dec = forward(params, cfg8, {"tokens": toks[:, S:S + 1]},
                  mode="decode", states=st)["logits"]
    rel = float(jnp.max(jnp.abs(full - dec))) / float(jnp.abs(full).max())
    assert rel < 0.05, rel


def test_int8_cache_struct():
    from repro.models.attention import init_kv_cache
    cfg = dataclasses.replace(reduced(get_config("musicgen-large")),
                              kv_cache_dtype="int8")
    blk = cfg.layer_pattern()[0]
    c = init_kv_cache(cfg, blk, 2, 32)
    assert c["k"].dtype == jnp.int8
    assert c["k_scale"].shape == (2, cfg.num_kv_heads,
                                  min(32, blk.window or 32), 1)


def test_moe_layout_roundtrip():
    cfg = dataclasses.replace(
        reduced(get_config("mixtral-8x22b")), d_ff=96,
        moe=MoEConfig(num_experts=4, top_k=2))
    M = 8
    w = jax.random.normal(jax.random.key(0), (3, 4, 64, 96))  # stacked
    back = moelib.layout_cols_inv(moelib.layout_cols(w, cfg, M), cfg, M)
    np.testing.assert_array_equal(np.asarray(w), np.asarray(back))
    wd = jax.random.normal(jax.random.key(1), (3, 4, 96, 64))
    back = moelib.layout_rows_inv(moelib.layout_rows(wd, cfg, M), cfg, M)
    np.testing.assert_array_equal(np.asarray(wd), np.asarray(back))


def test_prepare_tree_marks_by_ndim():
    cfg = dataclasses.replace(
        reduced(get_config("dbrx-132b")), d_ff=96,
        moe=MoEConfig(num_experts=4, top_k=2))
    p = moelib.init_moe(jax.random.key(0), cfg, jnp.float32)
    tree = {"layers": {"flat": [{"ffn": p}]}}
    out = moelib.prepare_tree(tree, cfg, M=4)
    assert out["layers"]["flat"][0]["ffn"]["we_up"].ndim == 4
    assert p["we_up"].ndim == 3  # untouched original


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, jax, jax.numpy as jnp
    from repro.configs import get_config, reduced, MoEConfig
    from repro.launch import sharding as shlib
    from repro.models import moe as moelib
    cfg = dataclasses.replace(
        reduced(get_config("mixtral-8x22b")), d_ff=96, d_model=64,
        moe=MoEConfig(num_experts=4, top_k=2, capacity_factor=2.0),
        moe_stationary_serve=True, moe_stationary_max_tokens=4096)
    p = moelib.init_moe(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (8, 4, cfg.d_model))
    ref, _ = moelib.apply_moe(p, cfg, x)
    worst = 0.0
    for shape in [(2, 4), (4, 2), (8, 1), (1, 8)]:
        mesh = jax.make_mesh(shape, ("data", "model"))
        ctx = shlib.ShardingContext(mesh)
        with mesh:
            with shlib.use(ctx):
                out, _ = jax.jit(
                    lambda p, x: moelib.apply_moe(p, cfg, x))(p, x)
        worst = max(worst, float(jnp.max(jnp.abs(out - ref))))
    print("WORST", worst)
    assert worst < 1e-4, worst
""")


@pytest.mark.slow
def test_stationary_moe_matches_local_multidevice():
    import os
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env = dict(os.environ, PYTHONPATH=src)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr


def test_microbatch_dp_divisibility_logic():
    """B=256, k_cfg=16, dp=32 -> picks k=8 (B/k divides dp)."""
    B, dp, k = 256, 32, 16
    while B % k:
        k -= 1
    while k > 1 and ((B // k) % dp or B % k):
        k -= 1
    assert k == 8
    # single pod dp=16 keeps k=16
    k, dp = 16, 16
    while k > 1 and ((B // k) % dp or B % k):
        k -= 1
    assert k == 16
