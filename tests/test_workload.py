"""repro.workload: QoS classes, mixed traces, EDF queues, capability
descriptors, and the QoS-extended observation across sim + live."""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster import (DeadlineAwareScheduler, EdgeCluster,
                           PolicyScheduler, Request, evaluate_scheduler,
                           make_scheduler, poisson_trace, summarize)
from repro.configs import get_config, reduced
from repro.core.agents import AgentConfig
from repro.core.diffusion import DiffusionPolicyConfig
from repro.core.env import EnvParams, sample_episode
from repro.core.trainer import init_agents
from repro.models.transformer import init_params
from repro.serving.builders import build_fleet
from repro.serving.engine import ServeEngine
from repro.workload import (DEFAULT_MIX, EDFQueue, QoSClass,
                            cold_token_seconds, normalized_weights,
                            qos_poisson_trace, scaled)

ACFG = AgentConfig(train_after=10, replay_capacity=60, batch_size=16,
                   diffusion=DiffusionPolicyConfig(num_steps=2))


def _engine(arch="qwen2-1.5b", num_layers=2, kv_slots=2, max_len=48,
            seed=0, **kw):
    cfg = dataclasses.replace(reduced(get_config(arch)),
                              num_layers=num_layers)
    params = init_params(jax.random.key(seed), cfg)
    return ServeEngine(cfg, params, max_len=max_len, kv_slots=kv_slots,
                      **kw)


def _req(rid, *, qos=None, deadline=None, arrival=0.0, tokens=4):
    prompt = jnp.zeros((1, 4), jnp.int32)
    return Request(rid=rid, prompt=prompt, max_new_tokens=tokens,
                   arrival_s=arrival, qos=qos, deadline_s=deadline)


# ---------------------------------------------------------------------------
# QoS classes + mixed-class traces
# ---------------------------------------------------------------------------


def test_qos_class_validation():
    with pytest.raises(ValueError):
        QoSClass("bad", priority=0.0)
    with pytest.raises(ValueError):
        QoSClass("bad", deadline_s=-1.0)
    with pytest.raises(ValueError):
        QoSClass("bad", z_range=(8, 4))
    c = scaled(QoSClass("ok", deadline_s=2.0), deadline_s=5.0,
               z_range=(2, 4), model_pref="xlstm-350m")
    assert c.deadline_s == 5.0 and c.z_range == (2, 4)
    assert c.model_pref == "xlstm-350m" and not c.best_effort
    classes, w = normalized_weights(DEFAULT_MIX)
    assert len(classes) == 3 and abs(sum(w) - 1.0) < 1e-12


def test_qos_trace_deterministic_given_seed():
    kw = dict(rate=50.0, prompt_len=8, vocab_size=64, num_origins=3,
              seed=7, mix=DEFAULT_MIX)
    a = qos_poisson_trace(20, **kw)
    b = qos_poisson_trace(20, **kw)
    for ra, rb in zip(a, b):
        assert ra.arrival_s == rb.arrival_s
        assert ra.qos.name == rb.qos.name
        assert ra.max_new_tokens == rb.max_new_tokens
        assert ra.deadline_s == rb.deadline_s
        assert ra.origin == rb.origin
        np.testing.assert_array_equal(np.asarray(ra.prompt),
                                      np.asarray(rb.prompt))
    # a different seed must actually change the draw
    c = qos_poisson_trace(20, **{**kw, "seed": 8})
    assert any(ra.arrival_s != rc.arrival_s for ra, rc in zip(a, c))


def test_qos_trace_class_proportions_and_ranges():
    trace = qos_poisson_trace(400, rate=100.0, prompt_len=8,
                              vocab_size=64, mix=DEFAULT_MIX, seed=3)
    classes, w = normalized_weights(DEFAULT_MIX)
    counts = {c.name: 0 for c in classes}
    for r in trace:
        counts[r.qos.name] += 1
        lo, hi = r.qos.z_range
        assert lo <= r.max_new_tokens <= hi
    for c, wi in zip(classes, w):
        assert abs(counts[c.name] / len(trace) - wi) < 0.1, c.name


def test_qos_trace_deadlines_absolute_and_monotone():
    trace = qos_poisson_trace(60, rate=30.0, prompt_len=8,
                              vocab_size=64, mix=DEFAULT_MIX, seed=0)
    by_class = {}
    for r in trace:
        if r.qos.best_effort:
            assert r.deadline_s is None
            continue
        # absolute deadline = arrival + the class budget
        assert abs(r.deadline_s - (r.arrival_s + r.qos.deadline_s)) < 1e-9
        assert abs(r.deadline_budget_s - r.qos.deadline_s) < 1e-9
        by_class.setdefault(r.qos.name, []).append(r.deadline_s)
    assert by_class, "trace drew no deadline-carrying request"
    for name, deadlines in by_class.items():
        # arrivals are time-ordered, so per-class deadlines must be too
        assert deadlines == sorted(deadlines), name


def test_plain_trace_carries_no_qos():
    trace = poisson_trace(5, rate=10.0, prompt_len=8, max_new_tokens=4,
                          vocab_size=64, seed=1)
    for r in trace:
        assert r.qos is None and r.deadline_s is None
        assert r.model_pref is None and r.priority == 1.0
        assert r.deadline_budget_s is None


# ---------------------------------------------------------------------------
# EDF queues + engine-side priority admission
# ---------------------------------------------------------------------------


def test_edf_queue_orders_priority_then_deadline_then_fifo():
    hi = QoSClass("hi", priority=4.0, deadline_s=9.0)
    lo = QoSClass("lo", priority=1.0, deadline_s=9.0)
    q = EDFQueue()
    q.append(_req(0, qos=lo, deadline=5.0))
    q.append(_req(1, qos=hi, deadline=8.0))
    q.append(_req(2, qos=hi, deadline=2.0))
    q.append(_req(3, qos=hi, deadline=2.0, arrival=1.0))
    assert q[0].rid == 2
    assert [q.popleft().rid for _ in range(len(q))] == [2, 3, 1, 0]


def test_edf_queue_degrades_to_fifo_without_qos():
    q = EDFQueue()
    for rid in (3, 1, 4, 1, 5):
        q.append(_req(rid))
    assert [q.popleft().rid for _ in range(len(q))] == [3, 1, 4, 1, 5]
    q.append(_req(9))
    assert len(q) == 1 and bool(q)
    q.clear()
    assert len(q) == 0 and not q
    with pytest.raises(IndexError):
        q.popleft()


def test_engine_serves_high_priority_first():
    """With one dense slot, a high-priority request admitted later must
    still enter service before the queued best-effort ones."""
    engine = _engine(kv_slots=1, paged=False)
    hi = QoSClass("hi", priority=4.0, deadline_s=2.0)
    lo = QoSClass("lo", priority=1.0)
    prompt = jax.random.randint(jax.random.key(0), (1, 8), 0,
                                engine.cfg.vocab_size)
    a = Request(rid=0, prompt=prompt, max_new_tokens=2, qos=lo)
    b = Request(rid=1, prompt=prompt, max_new_tokens=2, qos=lo)
    c = Request(rid=2, prompt=prompt, max_new_tokens=2, qos=hi,
                deadline_s=2.0)
    for r in (a, b, c):
        engine.admit(r)
    done = engine.run_to_completion()
    assert len(done) == 3 and all(r.done for r in (a, b, c))
    # c overtakes b in the queue (a holds the only slot first)
    assert c.t_prefill_start < b.t_prefill_start
    assert c.missed is not None      # finish() resolved the deadline


# ---------------------------------------------------------------------------
# summarize(): robustness + per-class accounting
# ---------------------------------------------------------------------------


def test_summarize_empty_and_unfinished():
    empty = summarize([])
    assert empty["count"] == 0 and empty["unfinished"] == 0
    assert empty["mean_s"] == 0.0 and empty["deadline_miss_rate"] == 0.0
    assert empty["weighted_goodput"] == 0.0

    finished = _req(0, qos=QoSClass("hi", priority=4.0, deadline_s=9.0),
                    deadline=9.0)
    finished.t_enqueue, finished.t_prefill_start = 0.0, 0.1
    finished.t_prefill_end = 0.2
    finished.finish(0.5)
    never_started = _req(1, qos=QoSClass("lo"), tokens=8)
    late = _req(2, qos=QoSClass("hi", priority=4.0, deadline_s=1.0),
                deadline=1.0)
    late.t_enqueue = 0.0
    stats = summarize([finished, never_started, late])
    assert stats["count"] == 1 and stats["unfinished"] == 2
    assert stats["mean_s"] == pytest.approx(0.5)
    # the unfinished deadline-carrying request counts as a miss
    assert stats["deadline_miss_rate"] == pytest.approx(0.5)
    assert stats["weighted_goodput"] == pytest.approx(4.0 / 9.0)
    assert set(stats["classes"]) == {"hi", "lo"}
    assert stats["classes"]["lo"]["unfinished"] == 1
    assert stats["classes"]["hi"]["deadline_miss_rate"] == 0.5


def test_summarize_per_class_percentiles():
    hi = QoSClass("hi", priority=4.0, deadline_s=10.0)
    lo = QoSClass("lo", priority=1.0)
    reqs = []
    for i, (cls, delay) in enumerate([(hi, 0.2), (hi, 0.4), (lo, 2.0)]):
        r = _req(i, qos=cls,
                 deadline=10.0 if not cls.best_effort else None)
        r.t_enqueue, r.t_prefill_start = 0.0, 0.01
        r.t_prefill_end = 0.02
        r.finish(delay)
        reqs.append(r)
    stats = summarize(reqs)
    assert stats["classes"]["hi"]["count"] == 2
    assert stats["classes"]["hi"]["mean_s"] == pytest.approx(0.3)
    assert stats["classes"]["hi"]["max_s"] == pytest.approx(0.4)
    assert stats["classes"]["lo"]["p50_s"] == pytest.approx(2.0)
    assert stats["weighted_goodput"] == pytest.approx(1.0)
    assert stats["deadline_miss_rate"] == 0.0


# ---------------------------------------------------------------------------
# QoS-extended observation: sim env, schedulers, live validation
# ---------------------------------------------------------------------------


def test_env_state_dim_and_episode_shapes_with_qos():
    base = EnvParams(num_bs=3, num_slots=2, max_tasks=2)
    qos = dataclasses.replace(base, qos_mix=DEFAULT_MIX)
    assert base.state_dim == 2 + 3
    assert qos.state_dim == 3 + 2 * 3
    assert qos.has_qos and not base.has_qos
    assert qos.z_hi == max(base.z_range[1],
                           max(c.z_range[1] for c, _ in DEFAULT_MIX))
    ep = sample_episode(jax.random.key(0), qos)
    shape = (qos.num_slots, qos.max_tasks, qos.num_bs)
    assert ep.cls.shape == shape and ep.cls.dtype == jnp.int32
    assert int(ep.cls.max()) < len(DEFAULT_MIX)
    assert ep.deadline.shape == shape and ep.priority.shape == shape
    prios = sorted({c.priority for c, _ in DEFAULT_MIX})
    assert set(np.unique(np.asarray(ep.priority))) <= set(prios)
    # best-effort tasks carry an infinite deadline
    z = np.asarray(ep.z)
    cls = np.asarray(ep.cls)
    for i, (c, _) in enumerate(DEFAULT_MIX):
        m = cls == i
        if m.any():
            assert z[m].min() >= c.z_range[0]
            assert z[m].max() <= c.z_range[1]


def test_env_without_qos_unchanged():
    """The QoS fields must not perturb the legacy sampling path."""
    p = EnvParams(num_bs=2, num_slots=2, max_tasks=2)
    ep = sample_episode(jax.random.key(0), p)
    assert np.all(np.asarray(ep.cls) == 0)
    assert np.all(np.isinf(np.asarray(ep.deadline)))
    assert np.all(np.asarray(ep.priority) == 1.0)


def test_deadline_scheduler_picks_min_queue_plus_affinity():
    s = DeadlineAwareScheduler(3)
    assert s.state_dim == 3 + 2 * 3
    #        d    w    q1   q2   q3  slack aff1 aff2 aff3
    row = [0.5, 0.5, 0.9, 0.1, 0.5, 1.0, 0.0, 0.9, 0.1]
    a, _ = s.select_one(s.init_carry(), jnp.asarray(row), 0, 0,
                        jax.random.key(0))
    assert a == 2      # q+aff = [.9, 1.0, .6]


def test_deadline_scheduler_in_qos_sim():
    p = EnvParams(num_bs=2, num_slots=3, max_tasks=3, qos_mix=DEFAULT_MIX)
    r = evaluate_scheduler(DeadlineAwareScheduler(2), p, episodes=1,
                           key=jax.random.key(1))
    assert r["count"] > 0 and r["mean_s"] > 0
    assert 0.0 <= r["deadline_miss_rate"] <= 1.0
    assert 0.0 <= r["weighted_goodput"] <= 1.0
    assert set(r["classes"]) <= {c.name for c, _ in DEFAULT_MIX}
    for st in r["classes"].values():
        assert st["count"] > 0 and st["p99_s"] >= st["p50_s"]


def test_policy_state_dim_inferred_and_validated():
    """A policy trained on the base observation must be rejected by a
    QoS-observing cluster at construction time, with a clear message."""
    base = EnvParams(num_bs=2, num_slots=2, max_tasks=2)
    qos = dataclasses.replace(base, qos_mix=DEFAULT_MIX)
    for method in ("lad-ts", "dqn-ts"):
        st_base = init_agents(method, base, ACFG, jax.random.key(0))
        st_qos = init_agents(method, qos, ACFG, jax.random.key(0))
        s_base = PolicyScheduler(method, ACFG, st_base, num_engines=2,
                                 n_max=base.max_tasks)
        s_qos = PolicyScheduler(method, ACFG, st_qos, num_engines=2,
                                n_max=qos.max_tasks)
        assert s_base.state_dim == base.state_dim == 4
        assert s_qos.state_dim == qos.state_dim == 7
    engines = [_engine(seed=0), _engine(seed=1)]
    with pytest.raises(ValueError, match="state_dim"):
        EdgeCluster(engines, s_base, qos_obs=True)
    with pytest.raises(ValueError, match="state_dim"):
        EdgeCluster(engines, s_qos, qos_obs=False)
    # matching widths construct fine and auto-infer the QoS mode
    assert EdgeCluster(engines, s_qos).qos_obs
    assert not EdgeCluster(engines, s_base).qos_obs


# ---------------------------------------------------------------------------
# capability descriptors + heterogeneous fleets
# ---------------------------------------------------------------------------


def test_capability_cold_then_measured():
    engine = _engine()
    cap = engine.capability
    assert not cap.measured
    assert cap.arch == "qwen2-1.5b-smoke" or cap.arch == engine.cfg.name
    assert cap.token_seconds == pytest.approx(
        cold_token_seconds(engine.cfg), rel=1e-6)
    assert cap.rho_gcycles == pytest.approx(
        2.0 * engine.cfg.active_param_count() / 1e9)
    prompt = jax.random.randint(jax.random.key(0), (1, 8), 0,
                                engine.cfg.vocab_size)
    engine.admit(Request(rid=0, prompt=prompt, max_new_tokens=3))
    engine.run_to_completion()
    cap2 = engine.capability
    assert cap2.measured and cap2.tok_s > 0
    assert engine.est_token_seconds == pytest.approx(engine._ewma_tok_s)


def test_build_fleet_heterogeneous_archs_and_backends():
    fleet = build_fleet(("qwen2-1.5b", "xlstm-350m"), max_len=32,
                        kv_slots=2)
    assert [e.arch_id for e in fleet] == ["qwen2-1.5b", "xlstm-350m"]
    assert fleet[0].paged and not fleet[1].paged   # attention vs recurrent
    caps = [e.capability for e in fleet]
    assert caps[0].arch != caps[1].arch
    assert all(c.tok_s > 0 for c in caps)


def test_deadline_scheduler_drives_live_qos_cluster():
    """The same DeadlineAwareScheduler object runs the live fleet on the
    extended observation and the trace-level QoS accounting holds up."""
    fleet = build_fleet(("qwen2-1.5b", "xlstm-350m"), max_len=64,
                        kv_slots=2)
    vocab = min(e.cfg.vocab_size for e in fleet)
    mix = ((scaled(QoSClass("fast", priority=4.0, deadline_s=30.0),
                   z_range=(1, 2), model_pref="xlstm-350m"), 0.5),
           (QoSClass("slow", priority=1.0, z_range=(2, 4)), 0.5))
    cluster = EdgeCluster(fleet, DeadlineAwareScheduler(2), qos_obs=True)
    assert cluster.obs_dim == 3 + 2 * 2
    trace = poisson_trace(6, rate=50.0, prompt_len=8, max_new_tokens=4,
                          vocab_size=vocab, num_origins=2, seed=11,
                          qos_mix=mix)
    stats = summarize(cluster.run(trace))
    assert stats["count"] == 6 and stats["unfinished"] == 0
    assert stats["p99_s"] >= stats["p50_s"] > 0
    assert 0.0 <= stats["deadline_miss_rate"] <= 1.0
    assert 0.0 <= stats["weighted_goodput"] <= 1.0
    assert set(stats["classes"]) <= {"fast", "slow"}
