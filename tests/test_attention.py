"""Model-level attention: the chunked online-softmax path vs dense ref."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.models.attention import chunked_causal_attention

KEY = jax.random.key(7)


@pytest.mark.parametrize("S,win,cq,ck", [
    (256, None, 64, 64), (256, None, 256, 64), (128, 32, 32, 32),
    (512, 200, 128, 64), (64, None, 64, 64),
])
def test_chunked_vs_dense(S, win, cq, ck):
    B, H, KV, hd = 2, 4, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    out = chunked_causal_attention(q, k, v, window=win, q_chunk=cq,
                                   kv_chunk=ck)
    expected = ref.attention_ref(q.swapaxes(1, 2), k.swapaxes(1, 2),
                                 v.swapaxes(1, 2), window=win)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(expected.swapaxes(1, 2)),
                               atol=2e-4, rtol=2e-4)


def test_chunked_gradients_flow():
    B, S, H, KV, hd = 1, 64, 2, 1, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))

    def f(q, k, v):
        return chunked_causal_attention(q, k, v, q_chunk=32,
                                        kv_chunk=32).sum()

    grads = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    for g in grads:
        assert bool(jnp.isfinite(g).all())
        assert float(jnp.abs(g).max()) > 0


def test_q_offset_matches_suffix_of_longer_attention():
    """Decode-style partial query block with an offset must equal the
    corresponding rows of full attention."""
    B, S, H, KV, hd = 1, 128, 2, 2, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    full = chunked_causal_attention(q, k, v, q_chunk=32, kv_chunk=32)
    tail = chunked_causal_attention(q[:, 96:], k, v, q_offset=96,
                                    q_chunk=32, kv_chunk=32)
    np.testing.assert_allclose(np.asarray(full[:, 96:]), np.asarray(tail),
                               atol=1e-5, rtol=1e-5)
