"""Serving engine + data pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.data.pipeline import DataConfig, synth_batch
from repro.models import forward, init_params
from repro.serving.engine import ServeEngine

KEY = jax.random.key(0)


def test_engine_greedy_matches_forward():
    cfg = reduced(get_config("qwen2-1.5b"))
    params = init_params(KEY, cfg)
    B, S = 2, 16
    prompt = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    engine = ServeEngine(cfg, params, max_len=S + 8)
    res = engine.generate(prompt, 4)
    # first generated token == greedy argmax of prefill last_logits
    full = forward(params, cfg, {"tokens": prompt}, mode="prefill")
    want = np.asarray(jnp.argmax(full["last_logits"], -1))
    np.testing.assert_array_equal(np.asarray(res.tokens[0]), want)
    assert len(res.tokens) == 4
    assert res.prefill_s > 0 and res.decode_s > 0


def test_engine_queue_accumulates():
    cfg = reduced(get_config("xlstm-350m"))
    params = init_params(KEY, cfg)
    engine = ServeEngine(cfg, params, max_len=24)
    prompt = jax.random.randint(KEY, (1, 16), 0, cfg.vocab_size)
    engine.generate(prompt, 2)
    assert engine.pending_seconds >= 0.0


def test_engine_audio_tokens():
    cfg = reduced(get_config("musicgen-large"))
    params = init_params(KEY, cfg)
    prompt = jax.random.randint(KEY, (1, cfg.num_codebooks, 12), 0,
                                cfg.vocab_size)
    engine = ServeEngine(cfg, params, max_len=20)
    res = engine.generate(prompt, 3)
    assert np.asarray(res.tokens[0]).shape == (1, cfg.num_codebooks)


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "musicgen-large",
                                  "llava-next-mistral-7b"])
def test_synth_batch_shapes_and_determinism(arch):
    cfg = reduced(get_config(arch))
    dc = DataConfig(batch=2, seq_len=64)
    b1 = synth_batch(cfg, dc, step=3)
    b2 = synth_batch(cfg, dc, step=3)
    b3 = synth_batch(cfg, dc, step=4)
    for k in b1:
        np.testing.assert_array_equal(np.asarray(b1[k]),
                                      np.asarray(b2[k]))
    assert float(jnp.abs(b1["tokens"] - b3["tokens"]).max()) > 0
    assert int(b1["tokens"].max()) < cfg.vocab_size
    if cfg.vision_patches:
        assert b1["patches"].shape == (2, cfg.vision_patches,
                                       cfg.vision_dim)
        assert float(b1["mask"][:, :cfg.vision_patches].max()) == 0.0
    if cfg.num_codebooks:
        assert b1["tokens"].shape == (2, cfg.num_codebooks, 64)


def test_synth_batch_is_learnable_structure():
    """The ramp pattern must make next-token entropy < uniform."""
    cfg = reduced(get_config("qwen2-1.5b"))
    dc = DataConfig(batch=4, seq_len=128)
    b = synth_batch(cfg, dc, 0)
    toks = np.asarray(b["tokens"])
    diffs = np.diff(toks, axis=1) % cfg.vocab_size
    # dominated by the +3 ramp
    assert (np.abs(diffs - 3) < cfg.vocab_size // 32).mean() > 0.5
