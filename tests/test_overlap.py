"""Fleet-scale serving fast path: overlapped dispatch/collect parity,
the shared compiled-step cache, and sharded big-model engines."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.cluster import (EdgeCluster, Request, make_scheduler,
                           poisson_trace)
from repro.configs import get_config, reduced
from repro.launch.mesh import make_production_mesh, make_smoke_mesh
from repro.models.transformer import init_params
from repro.serving import compiled
from repro.serving.builders import build_fleet, build_sharded_engine
from repro.serving.engine import ServeEngine


def _engine(arch="qwen2-1.5b", num_layers=2, kv_slots=2, max_len=40,
            seed=0, **kw):
    cfg = dataclasses.replace(reduced(get_config(arch)),
                              num_layers=num_layers)
    params = init_params(jax.random.key(seed), cfg)
    return ServeEngine(cfg, params, max_len=max_len, kv_slots=kv_slots,
                       **kw)


def _mixed_fleet(seed0=3):
    """Paged (attention) + dense (recurrent) engines behind one cluster."""
    return build_fleet(["qwen2-1.5b", "xlstm-350m", "starcoder2-3b"],
                       max_len=48, depths=[2, 2, 2], seed0=seed0,
                       kv_slots=2, prefill_chunk=8, max_lanes=4)


def _drain(cluster, n):
    done = []
    for _ in range(10_000):
        if len(done) >= n and not cluster.busy:
            break
        done += cluster.step()
    return done


def _run_trace(overlap, seed0=3):
    """Submit an identical burst into an identical fresh fleet and drain."""
    engines = _mixed_fleet(seed0)
    cluster = EdgeCluster(engines, make_scheduler("jsq", len(engines)),
                          seed=11, overlap=overlap)
    trace = poisson_trace(8, rate=1e9, prompt_len=10, max_new_tokens=5,
                          vocab_size=min(e.cfg.vocab_size for e in engines),
                          num_origins=len(engines), seed=5)
    for r in trace:
        cluster.submit(r)
    done = _drain(cluster, len(trace))
    return engines, {r.rid: r for r in done}


# ---------------------------------------------------------------------------
# overlapped dispatch/collect parity vs serial stepping
# ---------------------------------------------------------------------------


def test_overlap_parity_with_serial_stepping():
    """Same burst through overlap=False and overlap=True clusters over a
    mixed paged+dense fleet: tokens bit-identical, same terminal statuses,
    ordered timestamps, and no leaked KV reservations."""
    eng_serial, serial = _run_trace(overlap=False)
    eng_overlap, overlap = _run_trace(overlap=True)
    assert serial.keys() == overlap.keys() and len(serial) == 8
    for rid, a in serial.items():
        b = overlap[rid]
        assert a.status == b.status == "ok"
        ta = np.asarray([np.asarray(t) for t in a.tokens])
        tb = np.asarray([np.asarray(t) for t in b.tokens])
        assert np.array_equal(ta, tb), f"rid {rid}: token divergence"
        assert b.t_enqueue <= b.t_prefill_start <= b.t_prefill_end \
            <= b.t_finish
    for e in eng_serial + eng_overlap:
        assert e.kv_leak == 0
        assert not e.has_work


def _admit(e, rid=0, plen=6, n_new=3):
    req = Request(rid=rid, prompt=np.zeros((1, plen), np.int32),
                  max_new_tokens=n_new)
    e.admit(req)
    return req


def test_engine_step_equals_dispatch_collect():
    """step() must be exactly dispatch()+collect(), and dispatching twice
    without collecting is a bug the engine refuses."""
    e = _engine()
    _admit(e, n_new=3)
    assert e.dispatch()
    assert e.pending_collect
    with pytest.raises(RuntimeError, match="uncollected"):
        e.dispatch()
    done = e.collect()
    assert e.pending_collect is False
    done += e.run_to_completion()
    assert len(done) == 1 and done[0].status == "ok"


def test_dispatch_returns_false_when_idle():
    e = _engine()
    assert e.dispatch() is False
    assert e.collect() == []


def test_ewma_updates_at_collect_not_dispatch():
    """Satellite: the tok/s EWMA must window dispatch-enqueue to
    collect-sync, so it only moves once the round's results landed."""
    e = _engine()
    _admit(e, n_new=4)
    assert e.dispatch()
    assert e._ewma_tok_s == 0.0      # decode round in flight, not timed yet
    e.collect()
    e.step()                         # a full decode round
    assert e._ewma_tok_s > 0.0


def test_fail_during_pending_drops_dispatched_round():
    """A crash between dispatch and collect must drop the in-flight round,
    orphan its requests, and zero the KV accounting."""
    e = _engine()
    _admit(e, n_new=4)
    assert e.dispatch()
    orphans = e.fail("injected")
    assert e._pending is None
    assert len(orphans) == 1
    assert e.kv_leak == 0
    assert e.collect() == []


# ---------------------------------------------------------------------------
# shared compiled-step cache
# ---------------------------------------------------------------------------


def test_same_config_engines_share_compiled_steps():
    compiled.clear_cache()
    a = _engine(arch="xlstm-350m", seed=0)   # dense slot pool
    b = _engine(arch="xlstm-350m", seed=1)
    assert a._prefill is b._prefill
    assert a._pool_decode is b._pool_decode
    info = compiled.cache_info()
    assert info["hits"] > 0
    p1 = _engine(arch="qwen2-1.5b", seed=0)  # paged page pool
    p2 = _engine(arch="qwen2-1.5b", seed=1)
    assert p1._paged_prefill is p2._paged_prefill
    assert p1._paged_decode is p2._paged_decode


def test_different_config_engines_do_not_share():
    compiled.clear_cache()
    a = _engine(arch="xlstm-350m", num_layers=2)
    b = _engine(arch="xlstm-350m", num_layers=3)   # different depth
    assert a._prefill is not b._prefill
    assert a._pool_decode is not b._pool_decode
    c = _engine(arch="qwen2-1.5b", kv_slots=2)
    d = _engine(arch="qwen2-1.5b", kv_slots=2, max_len=64)  # pool shape
    assert c._paged_decode is not d._paged_decode


def test_shared_steps_serve_identical_results():
    """Two engines behind ONE cached executable must still produce the
    same tokens as two independently jitted engines would: the cache may
    not entangle their states."""
    compiled.clear_cache()
    a = _engine(arch="xlstm-350m", seed=0)
    b = _engine(arch="xlstm-350m", seed=0)
    prompt = np.arange(8, dtype=np.int32)[None, :] % a.cfg.vocab_size
    ra = a.generate(prompt, 4)
    rb = b.generate(prompt, 4)
    assert np.array_equal(np.asarray(ra.tokens), np.asarray(rb.tokens))


# ---------------------------------------------------------------------------
# sharded big-model engines + mesh guard
# ---------------------------------------------------------------------------


def test_production_mesh_rejects_mismatched_device_count():
    """Satellite: asking for a 16-chip mesh on this runtime must fail
    loudly, naming the actual device count."""
    with pytest.raises(ValueError, match=str(jax.device_count())):
        make_production_mesh(shape=(4, 4), axes=("data", "model"))


def test_production_mesh_shape_axes_must_pair():
    with pytest.raises(ValueError):
        make_production_mesh(shape=(4, 4))


@pytest.mark.parametrize("arch", ["mixtral-8x22b", "dbrx-132b"])
def test_sharded_big_model_engine_serves(arch):
    """The big-model configs serve through the smoke mesh: params carry
    NamedShardings on the engine's mesh and a request completes."""
    eng = build_sharded_engine(arch, max_len=32, kv_slots=2,
                               prefill_chunk=8, seed=0)
    assert eng.mesh is not None
    shardings = {
        type(leaf.sharding).__name__
        for leaf in jax.tree_util.tree_leaves(eng.params)}
    assert shardings == {"NamedSharding"}
    meshes = {leaf.sharding.mesh
              for leaf in jax.tree_util.tree_leaves(eng.params)}
    assert meshes == {eng.mesh}
    req = Request(rid=0,
                  prompt=np.arange(6, dtype=np.int32)[None, :]
                  % eng.cfg.vocab_size,
                  max_new_tokens=3)
    eng.admit(req)
    done = eng.run_to_completion()
    assert len(done) == 1 and req.status == "ok"
    assert len(req.tokens) == 3
    assert eng.kv_leak == 0


def test_sharded_engine_matches_unsharded_tokens():
    """Smoke-mesh sharding must be semantically invisible: same config,
    same params, same prompt -> same tokens with and without the mesh."""
    cfg = dataclasses.replace(reduced(get_config("qwen2-1.5b")),
                              num_layers=2)
    params = init_params(jax.random.key(0), cfg)
    plain = ServeEngine(cfg, params, max_len=40, kv_slots=2,
                        prefill_chunk=8)
    sharded = ServeEngine(cfg, params, max_len=40, kv_slots=2,
                          prefill_chunk=8, mesh=make_smoke_mesh())
    prompt = np.arange(8, dtype=np.int32)[None, :] % cfg.vocab_size
    ra = plain.generate(prompt, 4)
    rb = sharded.generate(prompt, 4)
    assert np.array_equal(np.asarray(ra.tokens), np.asarray(rb.tokens))
