"""Serving correctness: prefill + decode must reproduce full-forward
logits exactly (cache semantics), for every architecture family and both
layer-evaluation modes (flat / grouped scan)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models import forward, init_params

S = 16
B = 2


def _cfg(arch, scan):
    cfg = reduced(get_config(arch))
    if cfg.moe:
        # exactness needs the no-drop capacity regime
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(
                cfg.moe, capacity_factor=float(cfg.moe.num_experts)))
    # exact-match tests use the full-precision cache; int8-cache accuracy
    # is covered separately in test_perf_features.py
    return dataclasses.replace(cfg, scan_layers=scan,
                               kv_cache_dtype="model")


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("scan", [False, True])
def test_decode_matches_full_forward(arch, scan):
    cfg = _cfg(arch, scan)
    key = jax.random.key(1)
    params = init_params(key, cfg)
    if cfg.num_codebooks:
        toks = jax.random.randint(key, (B, cfg.num_codebooks, S + 1), 0,
                                  cfg.vocab_size)
        pre = {"tokens": toks[..., :S]}
        nxt = {"tokens": toks[..., S:S + 1]}
    else:
        toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
        pre = {"tokens": toks[:, :S]}
        nxt = {"tokens": toks[:, S:S + 1]}
    extra = cfg.vision_patches or 0
    if extra:
        pre["patches"] = jax.random.normal(key, (B, extra, cfg.vision_dim))
    full_in = dict(pre)
    full_in["tokens"] = toks
    full = forward(params, cfg, full_in, mode="prefill")["last_logits"]
    st = forward(params, cfg, pre, mode="prefill",
                 max_len=extra + S + 8)["states"]
    dec = forward(params, cfg, nxt, mode="decode", states=st)["logits"]
    assert float(jnp.max(jnp.abs(full - dec))) < 2e-3, arch


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "xlstm-350m",
                                  "recurrentgemma-9b", "mixtral-8x22b"])
def test_multistep_decode_matches_teacher_forcing(arch):
    """Decode 4 tokens one-by-one == 4 separate teacher-forced prefills."""
    cfg = _cfg(arch, False)
    key = jax.random.key(2)
    params = init_params(key, cfg)
    N = 4
    toks = jax.random.randint(key, (B, S + N), 0, cfg.vocab_size)
    st = forward(params, cfg, {"tokens": toks[:, :S]}, mode="prefill",
                 max_len=S + N)["states"]
    for j in range(N):
        dec = forward(params, cfg, {"tokens": toks[:, S + j:S + j + 1]},
                      mode="decode", states=st)
        st = dec["states"]
        full = forward(params, cfg, {"tokens": toks[:, :S + j + 1]},
                       mode="prefill")["last_logits"]
        err = float(jnp.max(jnp.abs(full - dec["logits"])))
        assert err < 2e-3, (arch, j, err)


def test_windowed_ring_buffer_wraps_correctly():
    """Sliding-window arch: decode past the window must equal a fresh
    prefill over the last W tokens (ring-buffer correctness)."""
    cfg = _cfg("mixtral-8x22b", False)   # reduced window = 64
    W = cfg.sliding_window
    assert W == 64
    key = jax.random.key(3)
    params = init_params(key, cfg)
    total = W + 24
    toks = jax.random.randint(key, (B, total), 0, cfg.vocab_size)
    # prefill W, then decode 24 steps so the ring wraps
    st = forward(params, cfg, {"tokens": toks[:, :W]}, mode="prefill",
                 max_len=total)["states"]
    for j in range(W, total - 1):
        st = forward(params, cfg, {"tokens": toks[:, j:j + 1]},
                     mode="decode", states=st)["states"]
    dec = forward(params, cfg, {"tokens": toks[:, -1:]}, mode="decode",
                  states=st)["logits"]
    full = forward(params, cfg, {"tokens": toks}, mode="prefill")
    err = float(jnp.max(jnp.abs(full["last_logits"] - dec)))
    assert err < 2e-2, err
