"""Property-based tests on the AIGC edge environment invariants
(paper Eqns 2-4): queues never go negative, delays decompose exactly,
masked tasks are inert, and local processing is consistent."""
import jax
import jax.numpy as jnp
import numpy as np

from _property import given, settings, st

from repro.core import env as envlib

PARAMS = envlib.EnvParams(num_bs=4, num_slots=3, max_tasks=4)


def _episode(seed: int):
    return envlib.sample_episode(jax.random.key(seed), PARAMS)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000),
       actions=st.lists(st.integers(0, 3), min_size=4, max_size=4))
def test_delay_decomposition(seed, actions):
    """task_delays == transmission + compute + wait, computed by hand."""
    ep = _episode(seed)
    qs = envlib.init_queues(PARAMS)
    a = jnp.array(actions, jnp.int32)
    t, n = 0, 0
    delays = np.asarray(envlib.task_delays(PARAMS, ep, qs, t, n, a))
    for b in range(PARAMS.num_bs):
        tgt = actions[b]
        d = float(ep.d[t, n, b])
        wl = float(ep.rho[t, n, b] * ep.z[t, n, b])
        f = float(ep.f[tgt])
        manual = (d / float(ep.v_up[t, n, b])
                  + float(ep.d_out[t, n, b]) / float(ep.v_down[t, n, b])
                  + wl / f
                  + (float(qs.q_prev[tgt]) + float(qs.q_bef[tgt])) / f)
        assert abs(delays[b] - manual) < 1e-4


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_queue_never_negative_and_eqn4(seed):
    ep = _episode(seed)
    qs = envlib.init_queues(PARAMS)
    key = jax.random.key(seed + 1)
    for t in range(PARAMS.num_slots):
        for n in range(PARAMS.max_tasks):
            key, k = jax.random.split(key)
            a = jax.random.randint(k, (PARAMS.num_bs,), 0, PARAMS.num_bs)
            qs = envlib.apply_actions(PARAMS, ep, qs, t, n, a)
        before = np.asarray(qs.q_prev + qs.q_bef)
        qs = envlib.end_slot(PARAMS, ep, qs)
        after = np.asarray(qs.q_prev)
        assert (after >= -1e-6).all()
        # Eqn (4): q_t = max(q_{t-1} + placed - f*Delta, 0)
        expected = np.maximum(
            before - np.asarray(ep.f) * PARAMS.slot_seconds, 0.0)
        np.testing.assert_allclose(after, expected, atol=1e-5)
        assert float(jnp.abs(qs.q_bef).max()) == 0.0


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_masked_tasks_add_no_workload(seed):
    ep = _episode(seed)
    # force all tasks of slot 0 task-index >= 1 to be masked
    mask = np.asarray(ep.mask).copy()
    mask[0, 1:, :] = 0.0
    ep = ep._replace(mask=jnp.asarray(mask))
    qs = envlib.init_queues(PARAMS)
    a = jnp.zeros((PARAMS.num_bs,), jnp.int32)
    qs1 = envlib.apply_actions(PARAMS, ep, qs, 0, 1, a)
    np.testing.assert_allclose(np.asarray(qs1.q_bef),
                               np.asarray(qs.q_bef))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_state_vector_layout(seed):
    ep = _episode(seed)
    qs = envlib.QueueState(
        q_prev=jnp.arange(PARAMS.num_bs, dtype=jnp.float32),
        q_bef=jnp.zeros((PARAMS.num_bs,)))
    s = envlib.observe(PARAMS, qs, ep.d[0, 0],
                       ep.rho[0, 0] * ep.z[0, 0])
    assert s.shape == (PARAMS.num_bs, PARAMS.state_dim)
    np.testing.assert_allclose(np.asarray(s[:, 0]),
                               np.asarray(ep.d[0, 0]))
    # every BS sees the same global queue vector (Eqn 6)
    np.testing.assert_allclose(np.asarray(s[:, 2:]),
                               np.tile(np.arange(PARAMS.num_bs),
                                       (PARAMS.num_bs, 1)))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_faster_server_never_slower_when_idle(seed):
    """With empty queues, offloading to a strictly faster ES with equal
    rates gives strictly smaller compute+wait delay."""
    ep = _episode(seed)
    f = np.asarray(ep.f)
    fastest = int(np.argmax(f))
    slowest = int(np.argmin(f))
    if fastest == slowest:
        return
    qs = envlib.init_queues(PARAMS)
    a_fast = jnp.full((PARAMS.num_bs,), fastest, jnp.int32)
    a_slow = jnp.full((PARAMS.num_bs,), slowest, jnp.int32)
    d_fast = np.asarray(envlib.task_delays(PARAMS, ep, qs, 0, 0, a_fast))
    d_slow = np.asarray(envlib.task_delays(PARAMS, ep, qs, 0, 0, a_slow))
    assert (d_fast <= d_slow + 1e-6).all()
