"""Assigned-architecture configs must match the assignment table exactly."""
import pytest

from repro.configs import ARCH_IDS, all_configs, get_config, reduced

# (layers, d_model, heads, kv, d_ff, vocab)
EXPECTED = {
    "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
    "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
    "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
    "minitron-8b": (32, 4096, 32, 8, 16384, 256000),
    "starcoder2-7b": (32, 4608, 36, 4, 18432, 49152),
    "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
    "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
    "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
    "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
    "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936),
}

FAMILY = {
    "dbrx-132b": "moe", "starcoder2-3b": "dense", "musicgen-large": "audio",
    "minitron-8b": "dense", "starcoder2-7b": "dense",
    "mixtral-8x22b": "moe", "xlstm-350m": "ssm",
    "recurrentgemma-9b": "hybrid", "llava-next-mistral-7b": "vlm",
    "qwen2-1.5b": "dense",
}


def test_registry_complete():
    assert sorted(ARCH_IDS) == sorted(EXPECTED)
    assert len(all_configs()) == 10


@pytest.mark.parametrize("arch", sorted(EXPECTED))
def test_dims(arch):
    cfg = get_config(arch)
    L, d, H, KV, F, V = EXPECTED[arch]
    assert cfg.num_layers == L
    assert cfg.d_model == d
    assert cfg.num_heads == H
    assert cfg.num_kv_heads == KV
    assert cfg.d_ff == F
    assert cfg.vocab_size == V
    assert cfg.family == FAMILY[arch]
    assert cfg.citation


def test_family_traits():
    assert get_config("dbrx-132b").moe.num_experts == 16
    assert get_config("dbrx-132b").moe.top_k == 4
    assert get_config("mixtral-8x22b").moe.num_experts == 8
    assert get_config("mixtral-8x22b").moe.top_k == 2
    assert get_config("mixtral-8x22b").sliding_window == 4096
    assert get_config("qwen2-1.5b").qkv_bias
    assert get_config("musicgen-large").num_codebooks == 4
    assert not get_config("musicgen-large").use_rope
    assert get_config("llava-next-mistral-7b").vision_patches == 576
    assert get_config("recurrentgemma-9b").local_window == 2048


def test_patterns_expand():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        pat = cfg.layer_pattern()
        assert len(pat) == cfg.num_layers
    # xLSTM mixes block kinds (sLSTM + mLSTM)
    mixers = {b.mixer for b in get_config("xlstm-350m").layer_pattern()}
    assert mixers == {"mlstm", "slstm"}
    # RecurrentGemma: 1 local-attn per 2 recurrent
    rg = get_config("recurrentgemma-9b").layer_pattern()
    attn = [b for b in rg if b.mixer == "attn"]
    rec = [b for b in rg if b.mixer == "rglru"]
    assert len(rec) > len(attn)
    assert all(b.window == 2048 for b in attn)


def test_subquadratic_flags():
    assert get_config("xlstm-350m").is_subquadratic()
    assert get_config("recurrentgemma-9b").is_subquadratic()
    assert get_config("mixtral-8x22b").is_subquadratic()   # native SWA
    # dense archs only via the beyond-paper long-context variant
    cfg = get_config("minitron-8b")
    assert cfg.long_context_window is not None


@pytest.mark.parametrize("arch", sorted(EXPECTED))
def test_reduced_is_small_and_same_family(arch):
    cfg = reduced(get_config(arch))
    assert cfg.num_layers == 2
    assert cfg.d_model <= 512
    assert cfg.family == get_config(arch).family
    if cfg.moe:
        assert cfg.moe.num_experts <= 4


def test_param_counts_plausible():
    # names encode scale: sanity-check the analytic count within 2x
    approx = {"dbrx-132b": 132e9, "mixtral-8x22b": 141e9,
              "qwen2-1.5b": 1.5e9, "starcoder2-3b": 3e9,
              "starcoder2-7b": 7e9, "minitron-8b": 8e9,
              "recurrentgemma-9b": 9e9, "xlstm-350m": 350e6}
    for arch, n in approx.items():
        got = get_config(arch).param_count()
        assert 0.5 * n < got < 2.2 * n, (arch, got, n)


def test_active_params_less_for_moe():
    for arch in ("dbrx-132b", "mixtral-8x22b"):
        cfg = get_config(arch)
        assert cfg.active_param_count() < cfg.param_count()
    cfg = get_config("qwen2-1.5b")
    assert cfg.active_param_count() == cfg.param_count()
