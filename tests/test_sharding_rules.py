"""Sharding rules: divisibility guards, expert fallbacks, state specs."""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.launch import sharding as shlib


def test_col_row_rules():
    sizes = {"data": 16, "model": 16}
    assert shlib._leaf_spec(("layers", "scan", "mixer", "wq"),
                            (28, 1536, 1536), sizes) == \
        P(None, "data", "model")
    assert shlib._leaf_spec(("layers", "scan", "mixer", "wo"),
                            (28, 1536, 1536), sizes) == \
        P(None, "model", "data")
    assert shlib._leaf_spec(("layers", "flat", "ffn", "w_down"),
                            (8960, 1536), sizes) == P("model", "data")


def test_vocab_rule_with_codebooks():
    sizes = {"data": 16, "model": 16}
    assert shlib._leaf_spec(("embed", "embed"), (151936, 1536), sizes) == \
        P("model", "data")
    assert shlib._leaf_spec(("embed", "embed"), (4, 2048, 2048), sizes) == \
        P(None, "model", "data")


def test_expert_rule_and_fallback():
    sizes = {"data": 16, "model": 16}
    # dbrx: 16 experts divide 16 -> expert parallel
    assert shlib._leaf_spec(("layers", "scan", "ffn", "we_up"),
                            (40, 16, 6144, 10752), sizes) == \
        P(None, "model", "data", None)
    # mixtral: 8 experts don't divide 16 -> fall back to d_ff
    assert shlib._leaf_spec(("layers", "scan", "ffn", "we_up"),
                            (56, 8, 6144, 16384), sizes) == \
        P(None, None, "data", "model")
    assert shlib._leaf_spec(("layers", "scan", "ffn", "we_down"),
                            (56, 8, 16384, 6144), sizes) == \
        P(None, None, "model", "data")


def test_indivisible_dims_replicate():
    sizes = {"data": 16, "model": 16}
    # norm scales / biases replicated
    assert shlib._leaf_spec(("final_norm", "scale"), (1536,), sizes) == \
        P(None)
    # odd dims fall back to replication rather than uneven shards
    assert shlib._leaf_spec(("m", "wq"), (17, 33), sizes) == P(None, None)


def test_state_specs():
    sizes_mesh = None  # only batch_axes used
    spec = shlib._state_leaf_spec(("scan", "k"), (12, 32, 8, 32768, 128),
                                  "data")
    assert spec == P(None, "data", None, "model", None)
    spec = shlib._state_leaf_spec(("flat", "C"), (2, 4, 256, 256), "data")
    assert spec == P("data", None, "model", None)
    spec = shlib._state_leaf_spec(("flat", "pos"), (), "data")
    assert spec == P()
    spec = shlib._state_leaf_spec(("flat", "h"), (2, 1024), "data")
    assert spec == P("data", None)


def test_state_specs_divisibility_guard():
    """An axis that doesn't divide the dim must replicate that dim, not
    emit an uneven NamedSharding (e.g. a 6-lane pool on 4-way 'data')."""
    sizes = {"data": 4, "model": 16}
    # 6 lanes % 4 != 0 -> batch dim replicated; kv_seq 32768 % 16 == 0
    spec = shlib._state_leaf_spec(("scan", "k"), (12, 6, 8, 32768, 128),
                                  "data", sizes)
    assert spec == P(None, None, None, "model", None)
    # seq dim indivisible by 'model' -> replicated, batch still sharded
    spec = shlib._state_leaf_spec(("scan", "k"), (12, 8, 8, 100, 128),
                                  "data", sizes)
    assert spec == P(None, "data", None, None, None)
    # tuple batch axes multiply: ('pod','data') = 8 doesn't divide 6
    spec = shlib._state_leaf_spec(("flat", "h"), (6, 1024),
                                  ("pod", "data"),
                                  {"pod": 2, "data": 4})
    assert spec == P(None, None)


def test_state_shardings_on_smoke_mesh():
    """state_shardings builds NamedShardings for every leaf on the mesh."""
    from jax.sharding import NamedSharding
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    states = {"k": jnp.zeros((2, 4, 16, 8)), "pos": jnp.zeros(())}
    shardings = shlib.state_shardings(mesh, states)
    for leaf in jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: isinstance(x, NamedSharding)):
        assert isinstance(leaf, NamedSharding)
        assert leaf.mesh == mesh
    placed = jax.device_put(states, shardings)   # shapes must be legal
    assert placed["k"].shape == (2, 4, 16, 8)


def test_param_pspecs_cover_full_tree():
    from repro.configs import get_config, reduced
    from repro.models import init_params
    cfg = reduced(get_config("dbrx-132b"))
    params = init_params(jax.random.key(0), cfg)
    specs = shlib.param_pspecs(params)
    n_leaves = len(jax.tree_util.tree_leaves(params))
    n_specs = len(jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P)))
    assert n_leaves == n_specs


def test_sharding_context_rules():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ctx = shlib.ShardingContext(mesh)
    assert ctx.spec("batch", None, "ff") == P("data", None, "model")
    # no active context -> act() is a no-op
    x = jnp.ones((2, 2))
    assert shlib.act(x, "batch", None) is x
