"""Property tests: circular experience pool semantics."""
import jax
import jax.numpy as jnp
import numpy as np

from _property import given, settings, st

from repro.core.replay import replay_add, replay_init, replay_sample

SPEC = {"v": jnp.zeros((2,)), "i": jnp.zeros((), jnp.int32)}


@settings(max_examples=30, deadline=None)
@given(n_add=st.integers(0, 25), cap=st.integers(1, 8),
       valid_pattern=st.lists(st.booleans(), min_size=25, max_size=25))
def test_size_and_ptr_track_valid_adds(n_add, cap, valid_pattern):
    state = replay_init(cap, SPEC)
    n_valid = 0
    for j in range(n_add):
        item = {"v": jnp.full((2,), float(j)),
                "i": jnp.asarray(j, jnp.int32)}
        state = replay_add(state, item, valid_pattern[j])
        n_valid += bool(valid_pattern[j])
    assert int(state.size) == min(n_valid, cap)
    assert int(state.ptr) == n_valid % cap


@settings(max_examples=20, deadline=None)
@given(cap=st.integers(2, 10), extra=st.integers(0, 15))
def test_wraparound_keeps_most_recent(cap, extra):
    state = replay_init(cap, SPEC)
    total = cap + extra
    for j in range(total):
        state = replay_add(state, {"v": jnp.full((2,), float(j)),
                                   "i": jnp.asarray(j, jnp.int32)}, True)
    kept = set(np.asarray(state.data["i"]).tolist())
    expected = set(range(total - cap, total))
    assert kept == expected


def test_sample_only_returns_valid_entries():
    state = replay_init(10, SPEC)
    for j in range(3):
        state = replay_add(state, {"v": jnp.full((2,), float(j + 1)),
                                   "i": jnp.asarray(j + 1, jnp.int32)},
                           True)
    batch = replay_sample(state, jax.random.key(0), 64)
    vals = set(np.asarray(batch["i"]).tolist())
    assert vals <= {1, 2, 3}


def test_invalid_adds_never_visible():
    state = replay_init(4, SPEC)
    state = replay_add(state, {"v": jnp.ones((2,)),
                               "i": jnp.asarray(7, jnp.int32)}, True)
    state = replay_add(state, {"v": jnp.full((2,), 99.0),
                               "i": jnp.asarray(99, jnp.int32)}, False)
    batch = replay_sample(state, jax.random.key(1), 32)
    assert set(np.asarray(batch["i"]).tolist()) == {7}
