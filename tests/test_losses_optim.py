"""Chunked CE vs dense reference; AdamW behaviour; checkpoint round-trip."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.models import apply_head, init_params
from repro.train import optimizer as opt_lib
from repro.train.checkpoint import restore_checkpoint, save_checkpoint
from repro.train.losses import chunked_ce_loss

KEY = jax.random.key(0)


def _dense_ce(params, cfg, hidden, labels, mask=None):
    logits = apply_head(params, cfg, hidden).astype(jnp.float32)
    if cfg.num_codebooks:
        labels = labels.swapaxes(1, 2)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if cfg.num_codebooks:
        nll = nll.mean(-1)
    if mask is None:
        mask = jnp.ones(nll.shape)
    return (nll * mask).sum() / mask.sum()


def test_chunked_ce_matches_dense():
    cfg = reduced(get_config("qwen2-1.5b"))
    params = init_params(KEY, cfg)
    B, S = 2, 64
    h = jax.random.normal(KEY, (B, S, cfg.d_model))
    labels = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    got = chunked_ce_loss(params, cfg, h, labels, chunk=16)
    want = _dense_ce(params, cfg, h, labels)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


def test_chunked_ce_codebooks():
    cfg = reduced(get_config("musicgen-large"))
    params = init_params(KEY, cfg)
    B, S, K = 2, 32, cfg.num_codebooks
    h = jax.random.normal(KEY, (B, S, cfg.d_model))
    labels = jax.random.randint(KEY, (B, K, S), 0, cfg.vocab_size)
    got = chunked_ce_loss(params, cfg, h, labels, chunk=8)
    want = _dense_ce(params, cfg, h, labels)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


def test_chunked_ce_respects_mask():
    cfg = reduced(get_config("qwen2-1.5b"))
    params = init_params(KEY, cfg)
    B, S = 2, 32
    h = jax.random.normal(KEY, (B, S, cfg.d_model))
    labels = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    mask = jnp.zeros((B, S)).at[:, S // 2:].set(1.0)
    got = chunked_ce_loss(params, cfg, h, labels, mask, chunk=8)
    want = _dense_ce(params, cfg, h, labels, mask)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)
    # corrupting masked labels must not change the loss
    bad = labels.at[:, 0].set(0)
    got2 = chunked_ce_loss(params, cfg, h, bad, mask, chunk=8)
    np.testing.assert_allclose(float(got), float(got2), rtol=1e-6)


def test_adamw_minimises_quadratic():
    cfg = opt_lib.AdamWConfig(learning_rate=0.1, weight_decay=0.0,
                              warmup_steps=1, total_steps=200,
                              grad_clip_norm=None)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt_lib.init(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, _ = opt_lib.update(cfg, grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_grad_clip_bounds_update():
    cfg = opt_lib.AdamWConfig(learning_rate=1.0, grad_clip_norm=1.0,
                              weight_decay=0.0, warmup_steps=1,
                              total_steps=10)
    params = {"w": jnp.zeros((3,))}
    state = opt_lib.init(params)
    _, _, metrics = opt_lib.update(cfg, {"w": jnp.full((3,), 1e6)}, state,
                                   params)
    assert float(metrics["grad_norm"]) > 1e5  # reported pre-clip


def test_checkpoint_roundtrip(tmp_path):
    cfg = reduced(get_config("xlstm-350m"))
    params = init_params(KEY, cfg)
    opt_state = opt_lib.init(params)
    path = os.path.join(tmp_path, "ckpt.npz")
    save_checkpoint(path, params, opt_state, step=17)
    p2, o2, step = restore_checkpoint(path, params, opt_state)
    assert step == 17
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(p2)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_bf16_checkpoint_roundtrip(tmp_path):
    params = {"w": jnp.arange(8, dtype=jnp.bfloat16) / 3}
    path = os.path.join(tmp_path, "bf16.npz")
    save_checkpoint(path, params)
    p2, _, _ = restore_checkpoint(path, params)
    assert p2["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(params["w"], np.float32),
                                  np.asarray(p2["w"], np.float32))
