"""Paged KV serving: kernel vs dense oracle, allocator invariants,
chunked-prefill interleaving, dense/paged engine parity, and the
prefix-sharing cache (refcounts, COW forks, LRU eviction)."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster.request import Request
from repro.configs import get_config, reduced
from repro.kernels import ref
from repro.kernels.decode_attention import paged_flash_decode
from repro.models.transformer import init_params
from repro.serving.engine import ServeEngine
from repro.serving.paged_kv import (BlockTable, PagePool, PrefixCache,
                                    paged_supported)

KEY = jax.random.key(0)

# CI runs the kernel-parity tests twice: REPRO_PAGED_TEST_MODE=interpret
# exercises the Pallas kernel through its interpreter, =default goes
# through the ops wrapper's backend-default pick (the compiled kernel on
# TPU, the XLA gather oracle elsewhere) — the exact path serving uses.
_MODE = os.environ.get("REPRO_PAGED_TEST_MODE", "interpret")


def _kernel_paged_decode(q, kp, vp, tbl, lens):
    if _MODE == "default":
        from repro.kernels import ops
        return ops.paged_flash_decode(q, kp, vp, tbl, lens, interpret=None)
    return paged_flash_decode(q, kp, vp, tbl, lens, interpret=True)


# ---------------------------------------------------------------------------
# kernel: paged gather == dense attention over the same tokens
# ---------------------------------------------------------------------------


def _paged_case(B, KV, H, hd, ps, npages, lengths, seed=0):
    """Random pool + per-sequence scrambled page tables, plus the dense
    (B, KV, S, hd) cache holding the same tokens in order."""
    rng = np.random.default_rng(seed)
    pool_pages = 1 + B * npages                    # page 0 = null
    perm = 1 + rng.permutation(B * npages)         # scrambled, non-contig
    tables = perm.reshape(B, npages).astype(np.int32)
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
    k_pages = jax.random.normal(ks[1], (pool_pages, KV, ps, hd), jnp.float32)
    v_pages = jax.random.normal(ks[2], (pool_pages, KV, ps, hd), jnp.float32)
    # dense view: logical position p of sequence b lives at
    # page tables[b, p // ps], slot p % ps
    kp, vp = np.asarray(k_pages), np.asarray(v_pages)
    S = npages * ps
    kd = np.stack([kp[tables[b]].transpose(1, 0, 2, 3).reshape(KV, S, hd)
                   for b in range(B)])
    vd = np.stack([vp[tables[b]].transpose(1, 0, 2, 3).reshape(KV, S, hd)
                   for b in range(B)])
    return (q, k_pages, v_pages, jnp.asarray(tables),
            jnp.asarray(lengths, jnp.int32), jnp.asarray(kd),
            jnp.asarray(vd))


def test_paged_kernel_matches_dense_ref_ragged_scrambled():
    """Kernel (mode per REPRO_PAGED_TEST_MODE) vs the dense decode
    oracle: ragged lengths (including a partial last page and a
    single-token sequence) through deliberately non-contiguous page
    tables."""
    B, KV, H, hd, ps, npages = 4, 2, 8, 64, 8, 6
    lengths = [1, 7, 23, 48]        # mid-page, full, ragged, exactly full
    q, kp, vp, tbl, lens, kd, vd = _paged_case(B, KV, H, hd, ps, npages,
                                               lengths)
    got = _kernel_paged_decode(q, kp, vp, tbl, lens)
    want = ref.decode_ref(q, kd, vd, lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-3, rtol=1e-3)
    # and the XLA serving-path oracle agrees with both
    ora = ref.paged_decode_ref(q, kp, vp, tbl, lens)
    np.testing.assert_allclose(np.asarray(ora), np.asarray(want),
                               atol=1e-3, rtol=1e-3)


def test_paged_kernel_ignores_unmapped_table_entries():
    """Entries past ceil(length/ps) may point anywhere (here: all at the
    null page) without changing the output."""
    B, KV, H, hd, ps, npages = 2, 2, 4, 32, 8, 4
    lengths = [9, 17]
    q, kp, vp, tbl, lens, kd, vd = _paged_case(B, KV, H, hd, ps, npages,
                                               lengths, seed=3)
    base = _kernel_paged_decode(q, kp, vp, tbl, lens)
    tbl2 = np.asarray(tbl).copy()
    for b, ln in enumerate(lengths):
        tbl2[b, -(-ln // ps):] = 0                 # null out unmapped tail
    got = _kernel_paged_decode(q, kp, vp, jnp.asarray(tbl2), lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(base),
                               atol=1e-6, rtol=1e-6)


# ---------------------------------------------------------------------------
# allocator invariants
# ---------------------------------------------------------------------------


def test_page_pool_alloc_free_recycle():
    pool = PagePool(num_pages=8, page_size=4)
    assert pool.num_free == 7                      # page 0 reserved
    a = pool.alloc(3)
    b = pool.alloc(2)
    assert 0 not in a + b                          # null page never leaves
    assert len(set(a + b)) == 5 and pool.num_free == 2
    pool.free(a)
    assert pool.num_free == 5
    c = pool.alloc(5)                              # recycles a's pages
    assert set(a) <= set(c) and pool.num_free == 0
    with pytest.raises(RuntimeError):
        pool.alloc(1)                              # exhausted
    pool.free(b)
    with pytest.raises(RuntimeError):
        pool.free(b)                               # double free
    pool.reset()
    assert pool.num_free == 7


def test_page_pool_pages_needed_and_block_table():
    pool = PagePool(num_pages=16, page_size=4)
    assert pool.pages_needed(0) == 0
    assert pool.pages_needed(1) == 1
    assert pool.pages_needed(4) == 1
    assert pool.pages_needed(5) == 2
    t = BlockTable(pool, tokens=9)                 # 3 pages
    assert len(t.pages) == 3
    row = t.row(6)
    assert row[:3] == t.pages and row[3:] == [0, 0, 0]
    with pytest.raises(ValueError):
        t.row(2)                                   # mapping doesn't fit
    free_before = pool.num_free
    t.release()
    assert pool.num_free == free_before + 3
    t.release()                                    # idempotent
    assert pool.num_free == free_before + 3


def test_paged_supported_gating():
    assert paged_supported(reduced(get_config("qwen2-1.5b")))
    assert not paged_supported(reduced(get_config("xlstm-350m")))


# ---------------------------------------------------------------------------
# engine: chunked prefill interleaving + capacity beyond kv_slots
# ---------------------------------------------------------------------------


def _paged_engine(**kw):
    cfg = dataclasses.replace(reduced(get_config("qwen2-1.5b")),
                              num_layers=2)
    params = init_params(KEY, cfg)
    kw.setdefault("max_len", 64)
    kw.setdefault("kv_slots", 2)
    kw.setdefault("page_size", 8)
    kw.setdefault("prefill_chunk", 8)
    return ServeEngine(cfg, params, paged=True, **kw)


def test_engine_paged_matches_dense_greedy():
    cfg = dataclasses.replace(reduced(get_config("qwen2-1.5b")),
                              num_layers=2)
    params = init_params(KEY, cfg)
    prompts = jax.random.randint(jax.random.key(1), (2, 16), 0,
                                 cfg.vocab_size)
    dense = ServeEngine(cfg, params, max_len=64, kv_slots=4, paged=False)
    paged = ServeEngine(cfg, params, max_len=64, kv_slots=4, paged=True,
                        page_size=8, prefill_chunk=8)
    r_d = dense.generate(prompts, 5)
    r_p = paged.generate(prompts, 5)
    for a, b in zip(r_d.tokens, r_p.tokens):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_chunked_prefill_interleaves_with_decode():
    """A short request must make decode progress BETWEEN the prefill
    chunks of a long prompt — the whole point of chunking."""
    eng = _paged_engine(max_lanes=4)
    vocab = eng.cfg.vocab_size
    long_p = jax.random.randint(jax.random.key(2), (1, 48), 0, vocab)
    short_p = jax.random.randint(jax.random.key(3), (1, 8), 0, vocab)
    rl = Request(rid=0, prompt=long_p, max_new_tokens=4)
    rs = Request(rid=1, prompt=short_p, max_new_tokens=3)
    eng.admit(rl)
    eng.admit(rs)
    saw_interleave = False
    for _ in range(200):
        if not eng.has_work:
            break
        eng.step()
        if rl.t_prefill_end is None and len(rs.tokens) > 1:
            saw_interleave = True                  # decode mid-prefill
    assert saw_interleave
    assert rl.done and rs.done
    assert len(rl.tokens) == 4 and len(rs.tokens) == 3


def test_paged_capacity_exceeds_kv_slots_and_recycles():
    """With the same KV budget that gives the dense engine 2 slots, the
    page pool holds 6 short requests in flight; every page is recycled."""
    eng = _paged_engine(kv_slots=2, max_lanes=8)
    vocab = eng.cfg.vocab_size
    prompt = jax.random.randint(jax.random.key(4), (1, 8), 0, vocab)
    reqs = [Request(rid=i, prompt=prompt, max_new_tokens=4)
            for i in range(6)]
    for r in reqs:
        eng.admit(r)
    done = eng.run_to_completion()
    assert len(done) == 6
    assert eng.peak_inflight > eng.kv_slots
    # every lane page came back; only the prefix cache's deliberate
    # residency (the one shared prompt block) stays allocated
    cached = eng.prefix_cached_pages
    assert cached == 1
    assert eng._pool.num_free == eng.num_pages - 1 - cached
    assert eng.kv_leak == 0
    # identical prompts + greedy -> identical tokens across all lanes
    for r in reqs[1:]:
        np.testing.assert_array_equal(np.stack(r.tokens),
                                      np.stack(reqs[0].tokens))


def test_engine_reset_clears_rate_and_rid_state():
    """reset() must restart the EWMA rate and request-id counter (stale
    values leaked scheduler backlog estimates across benchmark runs)."""
    eng = _paged_engine()
    prompt = jax.random.randint(jax.random.key(5), (1, 8),
                                0, eng.cfg.vocab_size)
    eng.generate(prompt, 3)
    assert eng._ewma_tok_s > 0 and eng._next_rid == 1
    eng.reset()
    assert eng._ewma_tok_s == 0.0
    assert eng._next_rid == 0
    assert eng.pending_seconds == 0.0
    assert eng.peak_inflight == 0
    # engine still serves correctly after reset
    res = eng.generate(prompt, 2)
    assert len(res.tokens) == 2


def test_mixed_paged_dense_fleet_parity():
    """A heterogeneous cluster mixes KV backends: the attention engine
    auto-selects the page pool, the xLSTM engine keeps the dense slot
    pool, and each model's greedy tokens in the shared fleet match a
    solo run of the same engine (the backends don't interfere)."""
    from repro.cluster import EdgeCluster, make_scheduler
    from repro.serving.builders import build_fleet

    fleet = build_fleet(("qwen2-1.5b", "xlstm-350m"), max_len=48,
                        kv_slots=2, depths=[2, 2])
    assert fleet[0].paged and not fleet[1].paged
    vocab = min(e.cfg.vocab_size for e in fleet)
    prompts = jax.random.randint(jax.random.key(6), (2, 8), 0, vocab)

    # solo references, one per backend
    solo = []
    for e, p in zip(fleet, prompts):
        r = Request(rid=0, prompt=p[None], max_new_tokens=4)
        e.admit(r)
        e.run_to_completion()
        solo.append(np.stack(r.tokens))
        e.reset()

    # same prompts through the mixed fleet, pinned by a local scheduler
    cluster = EdgeCluster(fleet, make_scheduler("local", 2))
    reqs = [Request(rid=i, prompt=prompts[i][None], max_new_tokens=4,
                    origin=i, arrival_s=0.0) for i in range(2)]
    done = cluster.run(reqs)
    assert len(done) == 2
    for i, r in enumerate(sorted(done, key=lambda r: r.rid)):
        assert r.engine_id == i
        np.testing.assert_array_equal(np.stack(r.tokens), solo[i])


# ---------------------------------------------------------------------------
# refcounted pool + prefix cache
# ---------------------------------------------------------------------------


def test_page_pool_refcounts_monotone():
    """retain/release move refcounts by exactly one; a page frees only at
    zero, and the pool rejects refs on pages it never handed out."""
    pool = PagePool(num_pages=8, page_size=4)
    pages = pool.alloc(2)
    assert all(pool.refcount(p) == 1 for p in pages)
    assert pool.total_refs == 2
    pool.retain(pages)
    assert all(pool.refcount(p) == 2 for p in pages)
    assert pool.total_refs == 4
    pool.release(pages)                        # 2 -> 1: still allocated
    assert pool.num_free == 5
    assert all(pool.refcount(p) == 1 for p in pages)
    pool.release(pages)                        # 1 -> 0: freed
    assert pool.num_free == 7 and pool.total_refs == 0
    assert all(pool.refcount(p) == 0 for p in pages)
    with pytest.raises(RuntimeError):
        pool.retain([pages[0]])                # retain of a freed page
    with pytest.raises(RuntimeError):
        pool.release([pages[0]])               # double free


def test_prefix_cache_match_insert_cow_and_clamp():
    pool = PagePool(num_pages=16, page_size=4)
    cache = PrefixCache(pool)
    p1 = jnp.arange(12, dtype=jnp.int32)[None]     # 3 full blocks
    t1 = BlockTable(pool, tokens=12)
    assert cache.insert(p1, t1.pages) == 3
    assert cache.size == 3
    m = cache.match(p1)
    assert list(m.pages) == t1.pages and m.cow_page is None
    assert m.tokens == 12
    # mid-block divergence -> 2 full blocks + a COW fork of block 2
    p2 = p1.at[0, 9].set(999)
    m2 = cache.match(p2)
    assert list(m2.pages) == t1.pages[:2]
    assert m2.cow_page == t1.pages[2] and m2.cow_tokens == 1
    assert m2.tokens == 9
    # max_tokens clamps BOTH full-block and in-block matching
    m3 = cache.match(p1, max_tokens=11)
    assert len(m3.pages) == 2 and m3.cow_tokens == 3
    assert m3.tokens == 11
    # re-inserting the same chain adds nothing and leaks no refs
    refs_before = pool.total_refs
    assert cache.insert(p1, t1.pages) == 0
    assert pool.total_refs == refs_before


def test_prefix_cache_acquire_release_and_lru_leaf_eviction():
    pool = PagePool(num_pages=16, page_size=4)
    cache = PrefixCache(pool)
    p1 = jnp.arange(12, dtype=jnp.int32)[None]
    t1 = BlockTable(pool, tokens=12)
    cache.insert(p1, t1.pages)
    m = cache.match(p1)
    cache.acquire(m)                           # lane's own refs on top
    assert all(pool.refcount(p) == 3 for p in t1.pages)  # t1+cache+match
    cache.release_match(m)
    t1.release()                               # cache alone keeps them
    assert pool.total_refs == 3 == cache.size
    # leaf-first LRU: only the chain tail is evictable; parents survive
    assert cache._evict_one()
    assert cache.size == 2 and cache.evictions == 1
    assert cache.match(p1).tokens == 8         # tail gone, parents match
    cache.clear()
    assert cache.size == 0 and pool.num_free == 15 and pool.total_refs == 0


def test_prefix_eviction_never_frees_referenced_page():
    """Evicting a cache entry whose page a live lane still shares must
    drop only the cache's reference — the page stays allocated."""
    pool = PagePool(num_pages=8, page_size=4)
    cache = PrefixCache(pool)
    p1 = jnp.arange(8, dtype=jnp.int32)[None]
    t1 = BlockTable(pool, tokens=8)            # the "live lane"
    cache.insert(p1, t1.pages)
    while cache._evict_one():
        pass
    assert cache.size == 0
    assert pool.num_free == 7 - 2              # lane still holds 2 pages
    assert all(pool.refcount(p) == 1 for p in t1.pages)
    t1.release()
    assert pool.num_free == 7


def test_ensure_free_reports_exhaustion():
    pool = PagePool(num_pages=4, page_size=4)  # 3 usable
    cache = PrefixCache(pool)
    t = BlockTable(pool, tokens=12)            # all 3 pages live
    assert not cache.ensure_free(1)            # nothing evictable
    t.release()
    assert cache.ensure_free(3)


# ---------------------------------------------------------------------------
# engine: prefix hits, COW forks, reset, eviction under pressure
# ---------------------------------------------------------------------------


def _run_one(eng, prompt, rid=0, tokens=4):
    r = Request(rid=rid, prompt=prompt, max_new_tokens=tokens)
    eng.admit(r)
    eng.run_to_completion()
    return r, np.stack([np.asarray(t).ravel() for t in r.tokens])


def test_prefix_hit_skips_prefill_tokens_identically():
    """A repeated prompt reuses 2 full blocks + a COW tail (clamped at
    prompt_len - 1) and emits byte-identical tokens to a cache-off
    engine; a fresh engine peeks 0 expected tokens."""
    eng = _paged_engine(max_lanes=4)
    off = _paged_engine(max_lanes=4, prefix_cache=False)
    vocab = eng.cfg.vocab_size
    prompt = jax.random.randint(jax.random.key(7), (1, 24), 0, vocab)
    r_probe = Request(rid=99, prompt=prompt, max_new_tokens=4)
    assert eng.expected_prefix_tokens(r_probe) == 0
    _, base = _run_one(off, prompt, rid=0)
    _, first = _run_one(eng, prompt, rid=0)
    assert eng.prefill_tokens_saved == 0       # cold cache: no hit
    assert eng.prefix_cached_pages == 3        # 24 tokens / page_size 8
    assert eng.expected_prefix_tokens(r_probe) == 23   # plen - 1 clamp
    r2, second = _run_one(eng, prompt, rid=1)
    assert r2.prefix_tokens == 23              # 2 full pages + 7 COW
    assert eng.prefill_tokens_saved == 23
    assert eng.cow_forks == 1
    assert eng.prefix_hit_rate == 0.5          # 1 hit / 2 lookups
    np.testing.assert_array_equal(first, base)
    np.testing.assert_array_equal(second, base)
    assert eng.kv_leak == 0
    assert off.prefix_lookups == 0             # cache-off: no index at all


def test_cow_fork_leaves_parent_chain_byte_identical():
    """Forking a cached page for a divergent lane must not write a single
    byte into the parent's pages, and the parent chain stays matchable."""
    eng = _paged_engine(kv_slots=4, max_lanes=4)
    vocab = eng.cfg.vocab_size
    parent = jax.random.randint(jax.random.key(8), (1, 24), 0, vocab)
    _run_one(eng, parent, rid=0, tokens=2)
    m = eng._prefix.match(parent)
    pages = np.asarray(m.pages)
    snap = [np.asarray(leaf[pages])
            for leaf in jax.tree_util.tree_leaves(eng._paged_states)]
    child = parent.at[0, 20].set((int(parent[0, 20]) + 1) % vocab)
    r1, _ = _run_one(eng, child, rid=1, tokens=2)
    assert r1.prefix_tokens == 20 and eng.cow_forks == 1
    after = [np.asarray(leaf[pages])
             for leaf in jax.tree_util.tree_leaves(eng._paged_states)]
    for a, b in zip(snap, after):
        np.testing.assert_array_equal(a, b)
    assert eng._prefix.match(parent).tokens == 24      # chain intact


def test_engine_reset_releases_prefix_cache_and_pool():
    eng = _paged_engine()
    vocab = eng.cfg.vocab_size
    prompt = jax.random.randint(jax.random.key(9), (1, 24), 0, vocab)
    _run_one(eng, prompt, rid=0)
    _run_one(eng, prompt, rid=1)
    assert eng.prefix_cached_pages > 0 and eng.prefill_tokens_saved > 0
    eng.reset()                                # asserts pool all-free inside
    assert eng.prefix_cached_pages == 0
    assert eng._pool.num_free == eng.num_pages - 1
    assert eng._pool.total_refs == 0
    assert eng.prefill_tokens_saved == 0 and eng.prefix_lookups == 0
    assert eng.cow_forks == 0 and eng.kv_leak == 0
    _, toks = _run_one(eng, prompt, rid=0, tokens=2)   # still serves
    assert toks.shape[0] == 2


def test_prefix_eviction_under_pool_pressure_still_admits():
    """Distinct prompts fill the pool with cached chains; later
    admissions must evict cached leaves (never lane pages) and proceed."""
    eng = _paged_engine(max_lanes=2)
    vocab = eng.cfg.vocab_size
    for i in range(8):
        prompt = jax.random.randint(jax.random.key(20 + i), (1, 24),
                                    0, vocab)
        r, _ = _run_one(eng, prompt, rid=i)
        assert len(r.tokens) == 4
        assert eng.kv_leak == 0
    assert eng.prefix_evictions > 0            # pressure actually evicted
    assert eng._pool.total_refs == eng.prefix_cached_pages


def test_prefix_cache_off_engine_matches_pre_cache_behaviour():
    """prefix_cache=False keeps the pool free of residual pages after
    each request — the pre-PR lifecycle."""
    eng = _paged_engine(prefix_cache=False)
    vocab = eng.cfg.vocab_size
    prompt = jax.random.randint(jax.random.key(11), (1, 24), 0, vocab)
    _run_one(eng, prompt, rid=0)
    assert eng._pool.num_free == eng.num_pages - 1
    assert eng.prefix_cached_pages == 0 and eng.kv_leak == 0


# ---------------------------------------------------------------------------
# scheduler + trace + summarize integration
# ---------------------------------------------------------------------------


def test_prefix_affinity_routes_to_warm_engine():
    """The prefix-affinity scheduler reads the appended expected-hit
    observation block and routes a repeated prompt to the engine holding
    its prefix — engine 1 here, against the argmin tie-default of 0."""
    from repro.cluster import EdgeCluster, make_scheduler
    from repro.serving.builders import build_engines

    engines = build_engines("qwen2-1.5b", 2, max_len=64, kv_slots=2,
                            depths=[2, 2], page_size=8, prefill_chunk=8)
    assert all(e.paged for e in engines)
    vocab = engines[0].cfg.vocab_size
    prompt = jax.random.randint(jax.random.key(12), (1, 24), 0, vocab)
    _run_one(engines[1], prompt, rid=0)        # warm ONLY engine 1
    sched = make_scheduler("prefix-affinity", 2)
    cluster = EdgeCluster(engines, sched)
    assert cluster.prefix_obs
    assert cluster.obs_dim == 2 + 2 * 2        # base (2+E) + hit block E
    req = Request(rid=1, prompt=prompt, max_new_tokens=2, arrival_s=0.0)
    row = np.asarray(cluster.observe(req))
    assert row.shape == (cluster.obs_dim,)
    assert row[-1] > row[-2] == 0.0            # hit feature: engine 1 only
    done = cluster.run([req])
    assert done[0].engine_id == 1
    assert done[0].prefix_tokens > 0


def test_prefix_affinity_state_dim_guard():
    """Suppressing the prefix block (and the fault layout it would alias
    into: base+E == 6 too) must fail construction with a message that
    names the prefix extension."""
    from repro.cluster import EdgeCluster, make_scheduler
    from repro.serving.builders import build_engines
    engines = build_engines("qwen2-1.5b", 2, max_len=48, kv_slots=2,
                            depths=[2, 2], page_size=8, prefill_chunk=8)
    sched = make_scheduler("prefix-affinity", 2)
    with pytest.raises(ValueError, match="prefix"):
        EdgeCluster(engines, sched, prefix_obs=False, fault_obs=False)


def test_poisson_trace_shared_prefix_and_stream_identity():
    """prefix_len>0 stamps the SAME system-prompt tokens onto the chosen
    fraction; prefix_len=0 consumes a bit-identical random stream to the
    legacy trace."""
    from repro.cluster import poisson_trace
    kw = dict(rate=50.0, prompt_len=16, max_new_tokens=2,
              vocab_size=97, num_origins=2, seed=3)
    shared = poisson_trace(12, prefix_len=12, prefix_frac=1.0, **kw)
    head0 = np.asarray(shared[0].prompt[..., :12])
    for r in shared:
        np.testing.assert_array_equal(np.asarray(r.prompt[..., :12]), head0)
    legacy = poisson_trace(6, **kw)
    zeroed = poisson_trace(6, prefix_len=0, prefix_frac=0.9, **kw)
    for a, b in zip(legacy, zeroed):
        np.testing.assert_array_equal(np.asarray(a.prompt),
                                      np.asarray(b.prompt))
        assert a.arrival_s == b.arrival_s
    # frac in (0,1): some share, some don't
    mixed = poisson_trace(40, prefix_len=12, prefix_frac=0.5, **kw)
    hits = sum(bool(np.array_equal(np.asarray(r.prompt[..., :12]), head0))
               for r in mixed)
    assert 0 < hits < 40


def test_summarize_and_sim_report_prefix_savings():
    from repro.cluster import summarize
    from repro.cluster.request import Request as Rq
    reqs = []
    for i, saved in enumerate([0, 10, 14]):
        r = Rq(rid=i, prompt=None, max_new_tokens=1, arrival_s=0.0)
        r.t_arrival, r.t_finish, r.status = 0.0, 1.0, "ok"
        r.prefix_tokens = saved
        reqs.append(r)
    out = summarize(reqs)
    assert out["prefill_tokens_saved"] == 24
    assert out["prefix_hit_rate"] == pytest.approx(2 / 3)
    # sim-side schema parity (no KV model -> identically zero, keys exist)
    from repro.cluster import evaluate_scheduler, make_scheduler
    from repro.core.env import EnvParams
    p = EnvParams(num_bs=2, num_slots=3, max_tasks=2)
    res = evaluate_scheduler(make_scheduler("jsq", 2), p, 1,
                             jax.random.key(0))
    assert res["prefill_tokens_saved"] == 0
    assert res["prefix_hit_rate"] == 0.0
