"""Paged KV serving: kernel vs dense oracle, allocator invariants,
chunked-prefill interleaving, and dense/paged engine parity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster.request import Request
from repro.configs import get_config, reduced
from repro.kernels import ref
from repro.kernels.decode_attention import paged_flash_decode
from repro.models.transformer import init_params
from repro.serving.engine import ServeEngine
from repro.serving.paged_kv import BlockTable, PagePool, paged_supported

KEY = jax.random.key(0)


# ---------------------------------------------------------------------------
# kernel: paged gather == dense attention over the same tokens
# ---------------------------------------------------------------------------


def _paged_case(B, KV, H, hd, ps, npages, lengths, seed=0):
    """Random pool + per-sequence scrambled page tables, plus the dense
    (B, KV, S, hd) cache holding the same tokens in order."""
    rng = np.random.default_rng(seed)
    pool_pages = 1 + B * npages                    # page 0 = null
    perm = 1 + rng.permutation(B * npages)         # scrambled, non-contig
    tables = perm.reshape(B, npages).astype(np.int32)
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
    k_pages = jax.random.normal(ks[1], (pool_pages, KV, ps, hd), jnp.float32)
    v_pages = jax.random.normal(ks[2], (pool_pages, KV, ps, hd), jnp.float32)
    # dense view: logical position p of sequence b lives at
    # page tables[b, p // ps], slot p % ps
    kp, vp = np.asarray(k_pages), np.asarray(v_pages)
    S = npages * ps
    kd = np.stack([kp[tables[b]].transpose(1, 0, 2, 3).reshape(KV, S, hd)
                   for b in range(B)])
    vd = np.stack([vp[tables[b]].transpose(1, 0, 2, 3).reshape(KV, S, hd)
                   for b in range(B)])
    return (q, k_pages, v_pages, jnp.asarray(tables),
            jnp.asarray(lengths, jnp.int32), jnp.asarray(kd),
            jnp.asarray(vd))


def test_paged_kernel_matches_dense_ref_ragged_scrambled():
    """Interpret-mode kernel vs the dense decode oracle: ragged lengths
    (including a partial last page and a single-token sequence) through
    deliberately non-contiguous page tables."""
    B, KV, H, hd, ps, npages = 4, 2, 8, 64, 8, 6
    lengths = [1, 7, 23, 48]        # mid-page, full, ragged, exactly full
    q, kp, vp, tbl, lens, kd, vd = _paged_case(B, KV, H, hd, ps, npages,
                                               lengths)
    got = paged_flash_decode(q, kp, vp, tbl, lens, interpret=True)
    want = ref.decode_ref(q, kd, vd, lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-3, rtol=1e-3)
    # and the XLA serving-path oracle agrees with both
    ora = ref.paged_decode_ref(q, kp, vp, tbl, lens)
    np.testing.assert_allclose(np.asarray(ora), np.asarray(want),
                               atol=1e-3, rtol=1e-3)


def test_paged_kernel_ignores_unmapped_table_entries():
    """Entries past ceil(length/ps) may point anywhere (here: all at the
    null page) without changing the output."""
    B, KV, H, hd, ps, npages = 2, 2, 4, 32, 8, 4
    lengths = [9, 17]
    q, kp, vp, tbl, lens, kd, vd = _paged_case(B, KV, H, hd, ps, npages,
                                               lengths, seed=3)
    base = paged_flash_decode(q, kp, vp, tbl, lens, interpret=True)
    tbl2 = np.asarray(tbl).copy()
    for b, ln in enumerate(lengths):
        tbl2[b, -(-ln // ps):] = 0                 # null out unmapped tail
    got = paged_flash_decode(q, kp, vp, jnp.asarray(tbl2), lens,
                             interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(base),
                               atol=1e-6, rtol=1e-6)


# ---------------------------------------------------------------------------
# allocator invariants
# ---------------------------------------------------------------------------


def test_page_pool_alloc_free_recycle():
    pool = PagePool(num_pages=8, page_size=4)
    assert pool.num_free == 7                      # page 0 reserved
    a = pool.alloc(3)
    b = pool.alloc(2)
    assert 0 not in a + b                          # null page never leaves
    assert len(set(a + b)) == 5 and pool.num_free == 2
    pool.free(a)
    assert pool.num_free == 5
    c = pool.alloc(5)                              # recycles a's pages
    assert set(a) <= set(c) and pool.num_free == 0
    with pytest.raises(RuntimeError):
        pool.alloc(1)                              # exhausted
    pool.free(b)
    with pytest.raises(RuntimeError):
        pool.free(b)                               # double free
    pool.reset()
    assert pool.num_free == 7


def test_page_pool_pages_needed_and_block_table():
    pool = PagePool(num_pages=16, page_size=4)
    assert pool.pages_needed(0) == 0
    assert pool.pages_needed(1) == 1
    assert pool.pages_needed(4) == 1
    assert pool.pages_needed(5) == 2
    t = BlockTable(pool, tokens=9)                 # 3 pages
    assert len(t.pages) == 3
    row = t.row(6)
    assert row[:3] == t.pages and row[3:] == [0, 0, 0]
    with pytest.raises(ValueError):
        t.row(2)                                   # mapping doesn't fit
    free_before = pool.num_free
    t.release()
    assert pool.num_free == free_before + 3
    t.release()                                    # idempotent
    assert pool.num_free == free_before + 3


def test_paged_supported_gating():
    assert paged_supported(reduced(get_config("qwen2-1.5b")))
    assert not paged_supported(reduced(get_config("xlstm-350m")))


# ---------------------------------------------------------------------------
# engine: chunked prefill interleaving + capacity beyond kv_slots
# ---------------------------------------------------------------------------


def _paged_engine(**kw):
    cfg = dataclasses.replace(reduced(get_config("qwen2-1.5b")),
                              num_layers=2)
    params = init_params(KEY, cfg)
    kw.setdefault("max_len", 64)
    kw.setdefault("kv_slots", 2)
    kw.setdefault("page_size", 8)
    kw.setdefault("prefill_chunk", 8)
    return ServeEngine(cfg, params, paged=True, **kw)


def test_engine_paged_matches_dense_greedy():
    cfg = dataclasses.replace(reduced(get_config("qwen2-1.5b")),
                              num_layers=2)
    params = init_params(KEY, cfg)
    prompts = jax.random.randint(jax.random.key(1), (2, 16), 0,
                                 cfg.vocab_size)
    dense = ServeEngine(cfg, params, max_len=64, kv_slots=4, paged=False)
    paged = ServeEngine(cfg, params, max_len=64, kv_slots=4, paged=True,
                        page_size=8, prefill_chunk=8)
    r_d = dense.generate(prompts, 5)
    r_p = paged.generate(prompts, 5)
    for a, b in zip(r_d.tokens, r_p.tokens):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_chunked_prefill_interleaves_with_decode():
    """A short request must make decode progress BETWEEN the prefill
    chunks of a long prompt — the whole point of chunking."""
    eng = _paged_engine(max_lanes=4)
    vocab = eng.cfg.vocab_size
    long_p = jax.random.randint(jax.random.key(2), (1, 48), 0, vocab)
    short_p = jax.random.randint(jax.random.key(3), (1, 8), 0, vocab)
    rl = Request(rid=0, prompt=long_p, max_new_tokens=4)
    rs = Request(rid=1, prompt=short_p, max_new_tokens=3)
    eng.admit(rl)
    eng.admit(rs)
    saw_interleave = False
    for _ in range(200):
        if not eng.has_work:
            break
        eng.step()
        if rl.t_prefill_end is None and len(rs.tokens) > 1:
            saw_interleave = True                  # decode mid-prefill
    assert saw_interleave
    assert rl.done and rs.done
    assert len(rl.tokens) == 4 and len(rs.tokens) == 3


def test_paged_capacity_exceeds_kv_slots_and_recycles():
    """With the same KV budget that gives the dense engine 2 slots, the
    page pool holds 6 short requests in flight; every page is recycled."""
    eng = _paged_engine(kv_slots=2, max_lanes=8)
    vocab = eng.cfg.vocab_size
    prompt = jax.random.randint(jax.random.key(4), (1, 8), 0, vocab)
    reqs = [Request(rid=i, prompt=prompt, max_new_tokens=4)
            for i in range(6)]
    for r in reqs:
        eng.admit(r)
    done = eng.run_to_completion()
    assert len(done) == 6
    assert eng.peak_inflight > eng.kv_slots
    assert eng._pool.num_free == eng.num_pages - 1
    # identical prompts + greedy -> identical tokens across all lanes
    for r in reqs[1:]:
        np.testing.assert_array_equal(np.stack(r.tokens),
                                      np.stack(reqs[0].tokens))


def test_engine_reset_clears_rate_and_rid_state():
    """reset() must restart the EWMA rate and request-id counter (stale
    values leaked scheduler backlog estimates across benchmark runs)."""
    eng = _paged_engine()
    prompt = jax.random.randint(jax.random.key(5), (1, 8),
                                0, eng.cfg.vocab_size)
    eng.generate(prompt, 3)
    assert eng._ewma_tok_s > 0 and eng._next_rid == 1
    eng.reset()
    assert eng._ewma_tok_s == 0.0
    assert eng._next_rid == 0
    assert eng.pending_seconds == 0.0
    assert eng.peak_inflight == 0
    # engine still serves correctly after reset
    res = eng.generate(prompt, 2)
    assert len(res.tokens) == 2


def test_mixed_paged_dense_fleet_parity():
    """A heterogeneous cluster mixes KV backends: the attention engine
    auto-selects the page pool, the xLSTM engine keeps the dense slot
    pool, and each model's greedy tokens in the shared fleet match a
    solo run of the same engine (the backends don't interfere)."""
    from repro.cluster import EdgeCluster, make_scheduler
    from repro.serving.builders import build_fleet

    fleet = build_fleet(("qwen2-1.5b", "xlstm-350m"), max_len=48,
                        kv_slots=2, depths=[2, 2])
    assert fleet[0].paged and not fleet[1].paged
    vocab = min(e.cfg.vocab_size for e in fleet)
    prompts = jax.random.randint(jax.random.key(6), (2, 8), 0, vocab)

    # solo references, one per backend
    solo = []
    for e, p in zip(fleet, prompts):
        r = Request(rid=0, prompt=p[None], max_new_tokens=4)
        e.admit(r)
        e.run_to_completion()
        solo.append(np.stack(r.tokens))
        e.reset()

    # same prompts through the mixed fleet, pinned by a local scheduler
    cluster = EdgeCluster(fleet, make_scheduler("local", 2))
    reqs = [Request(rid=i, prompt=prompts[i][None], max_new_tokens=4,
                    origin=i, arrival_s=0.0) for i in range(2)]
    done = cluster.run(reqs)
    assert len(done) == 2
    for i, r in enumerate(sorted(done, key=lambda r: r.rid)):
        assert r.engine_id == i
        np.testing.assert_array_equal(np.stack(r.tokens), solo[i])
