"""Property-test compatibility layer.

Uses real ``hypothesis`` when it is installed; otherwise provides a
deterministic fallback that replays a fixed number of seeded examples per
test (seeded from the test name, so runs are reproducible across
processes).  Test modules import ``given`` / ``settings`` / ``st`` from
here instead of hard-importing hypothesis, so tier-1 collection works in
a clean environment.
"""
try:
    from hypothesis import given, settings               # noqa: F401
    import hypothesis.strategies as st                   # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False
    import functools
    import inspect
    import random

    _FALLBACK_EXAMPLES = 8

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    class _St:
        """The subset of hypothesis.strategies the test suite uses."""

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return [elements.example(rng) for _ in range(n)]

            return _Strategy(draw)

    st = _St()

    def settings(max_examples=_FALLBACK_EXAMPLES, **_kwargs):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = min(getattr(wrapper, "_max_examples",
                                _FALLBACK_EXAMPLES), _FALLBACK_EXAMPLES)
                for ex in range(n):
                    rng = random.Random(f"{fn.__module__}.{fn.__name__}:{ex}")
                    drawn = {k: s.example(rng)
                             for k, s in strategies.items()}
                    fn(*args, **drawn, **kwargs)

            # hide the original parameters from pytest's fixture
            # resolution (the strategies supply them, not fixtures)
            wrapper.__dict__.pop("__wrapped__", None)
            wrapper.__signature__ = inspect.Signature()
            return wrapper

        return deco
