"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU).

Per the assignment: sweep shapes/dtypes and assert_allclose against the
ref.py oracle for every kernel.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import networks as nets
from repro.core.diffusion import make_schedule
from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.decode_attention import flash_decode
from repro.kernels.ladn_denoise import ladn_denoise_fused

KEY = jax.random.key(42)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

ATTN_CASES = [
    # (B, H, KV, S, hd, window, dtype)
    (2, 4, 2, 256, 64, None, jnp.float32),
    (1, 4, 4, 512, 128, None, jnp.float32),
    (2, 8, 2, 256, 128, 64, jnp.float32),
    (1, 2, 1, 128, 64, 32, jnp.float32),
    (1, 8, 8, 256, 64, None, jnp.bfloat16),
    (2, 2, 2, 384, 128, 128, jnp.bfloat16),
]


@pytest.mark.parametrize("B,H,KV,S,hd,win,dtype", ATTN_CASES)
def test_flash_attention_vs_ref(B, H, KV, S, hd, win, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, hd), dtype)
    k = jax.random.normal(ks[1], (B, KV, S, hd), dtype)
    v = jax.random.normal(ks[2], (B, KV, S, hd), dtype)
    out = flash_attention(q, k, v, window=win, bq=128, bk=128,
                          interpret=True)
    expected = ref.attention_ref(q, k, v, window=win)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_block_shape_independence():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 4, 512, 64))
    k = jax.random.normal(ks[1], (1, 2, 512, 64))
    v = jax.random.normal(ks[2], (1, 2, 512, 64))
    outs = [flash_attention(q, k, v, bq=bq, bk=bk, interpret=True)
            for bq, bk in [(64, 64), (128, 256), (512, 128)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# flash decode
# ---------------------------------------------------------------------------

DECODE_CASES = [
    (2, 8, 2, 1024, 64, 1024, jnp.float32),
    (1, 4, 1, 512, 128, 300, jnp.float32),
    (3, 16, 8, 256, 128, 77, jnp.float32),
    (2, 4, 4, 512, 64, 512, jnp.bfloat16),
    (1, 2, 1, 256, 128, 1, jnp.float32),     # single valid token
]


@pytest.mark.parametrize("B,H,KV,S,hd,L,dtype", DECODE_CASES)
def test_flash_decode_vs_ref(B, H, KV, S, hd, L, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    kc = jax.random.normal(ks[1], (B, KV, S, hd), dtype)
    vc = jax.random.normal(ks[2], (B, KV, S, hd), dtype)
    out = flash_decode(q, kc, vc, L, bk=128, interpret=True)
    expected = ref.decode_ref(q, kc, vc, L)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32),
                               atol=tol, rtol=tol)


def test_flash_decode_per_batch_lengths():
    ks = jax.random.split(KEY, 3)
    B, H, KV, S, hd = 3, 4, 2, 256, 64
    q = jax.random.normal(ks[0], (B, H, hd))
    kc = jax.random.normal(ks[1], (B, KV, S, hd))
    vc = jax.random.normal(ks[2], (B, KV, S, hd))
    lengths = jnp.array([10, 128, 256], jnp.int32)
    out = flash_decode(q, kc, vc, lengths, bk=64, interpret=True)
    expected = ref.decode_ref(q, kc, vc, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# fused LADN denoise chain
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("T,A,S_DIM,I", [(64, 20, 22, 5), (128, 10, 12, 3),
                                         (32, 30, 42, 8)])
def test_ladn_denoise_vs_ref(T, A, S_DIM, I):
    theta = nets.init_ladn(jax.random.key(0), S_DIM, A, (20, 20))
    sched = make_schedule(I)
    ks = jax.random.split(KEY, 3)
    x_I = jax.random.normal(ks[0], (T, A))
    s = jax.random.normal(ks[1], (T, S_DIM))
    noise = jax.random.normal(ks[2], (T, I, A))
    packed = ops.pack_ladn_weights(theta, S_DIM, A, 20)
    w1x, w1t, w1s, b1, w2, b2, w3, b3 = packed
    temb_w1 = ops._pad_to(
        nets.timestep_embed(jnp.arange(I, 0, -1)) @ w1t, 128, 1)
    x_p = ops._pad_to(x_I, 128, 1)
    s_p = ops._pad_to(s, 128, 1)
    n_p = ops._pad_to(noise, 128, 2)
    out = ladn_denoise_fused(x_p, s_p, n_p, temb_w1, w1x, w1s, b1, w2, b2,
                             w3, b3, sched, bt=32, interpret=True)[:, :A]
    expected = ref.ladn_denoise_ref(x_p, s_p, n_p, temb_w1, w1x, w1s, b1,
                                    w2, b2, w3, b3, sched)[:, :A]
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               atol=1e-4, rtol=1e-4)


def test_ladn_ops_wrapper_matches_model_chain():
    """ops.ladn_denoise (public API) == the agents' run_reverse_chain
    given identical noise handling (deterministic final step)."""
    S_DIM, A, I = 22, 20, 5
    theta = nets.init_ladn(jax.random.key(0), S_DIM, A, (20, 20))
    ks = jax.random.split(KEY, 3)
    T = 16
    x_I = jax.random.normal(ks[0], (T, A))
    s = jax.random.normal(ks[1], (T, S_DIM))
    x0, probs = ops.ladn_denoise(theta, x_I, s, ks[2], num_steps=I,
                                 state_dim=S_DIM, action_dim=A,
                                 interpret=True)
    assert x0.shape == (T, A)
    assert probs.shape == (T, A)
    np.testing.assert_allclose(np.asarray(probs.sum(-1)), 1.0, atol=1e-5)
    assert bool(jnp.isfinite(x0).all())
