"""End-to-end behaviour tests for the whole system.

1. A reduced model trains on the synthetic pipeline and the loss falls.
2. The scheduler routes a burst across heterogeneous ESs sensibly (the
   DEdgeAI story at smoke scale).
3. The launcher step functions lower + compile on a (1,1) mesh with the
   production sharding rules (miniature of the dry-run contract).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_shape, reduced
from repro.core import env as envlib
from repro.data.pipeline import DataConfig, synth_batch
from repro.launch import sharding as shlib
from repro.models import init_params
from repro.train import optimizer as opt_lib
from repro.train.steps import make_eval_step, make_train_step


def test_training_reduces_loss():
    cfg = reduced(get_config("qwen2-1.5b"))
    dc = DataConfig(batch=4, seq_len=64)
    params = init_params(jax.random.key(0), cfg)
    opt_state = opt_lib.init(params)
    opt_cfg = opt_lib.AdamWConfig(learning_rate=3e-3, warmup_steps=2,
                                  total_steps=40, weight_decay=0.0)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    first = None
    for s in range(40):
        params, opt_state, m = step(params, opt_state,
                                    synth_batch(cfg, dc, s))
        if first is None:
            first = float(m["loss"])
    eval_step = jax.jit(make_eval_step(cfg))
    final = float(eval_step(params, synth_batch(cfg, dc, 999)))
    assert final < first - 0.2, (first, final)


def test_scheduler_over_heterogeneous_capacity():
    """Opt-TS on a cluster with one fast ES routes most work there."""
    p = envlib.EnvParams(num_bs=3, num_slots=4, max_tasks=6,
                         f_range=(10.0, 10.0))
    ep = envlib.sample_episode(jax.random.key(0), p)
    f = np.asarray(ep.f).copy()
    f[:] = [50.0, 1.0, 1.0]
    ep = ep._replace(f=jnp.asarray(f))
    qs = envlib.init_queues(p)
    from repro.core.trainer import heuristic_actions
    counts = np.zeros(3)
    for n in range(p.max_tasks):
        a = heuristic_actions("opt-ts", p, ep, qs, 0, n, jax.random.key(n))
        qs = envlib.apply_actions(p, ep, qs, 0, n, a)
        counts += np.bincount(np.asarray(a), minlength=3)
    assert counts[0] > counts[1] + counts[2]


def test_step_functions_lower_on_mini_mesh():
    """The exact dry-run code path on a 1x1 mesh (single CPU device)."""
    from repro.launch.specs import input_specs, output_shardings
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for arch in ("qwen2-1.5b", "xlstm-350m"):
        cfg = dataclasses.replace(reduced(get_config(arch)),
                                  scan_layers=True)
        shape = dataclasses.replace(get_shape("train_4k"), seq_len=32,
                                    global_batch=2)
        ctx = shlib.ShardingContext(mesh)
        args, kwargs = input_specs(cfg, shape, mesh)
        step = make_train_step(cfg)
        with mesh:
            with shlib.use(ctx):
                out_shapes = jax.eval_shape(step, *args, **kwargs)
                outs = output_shardings(cfg, shape, mesh, out_shapes)
                compiled = jax.jit(step, out_shardings=outs).lower(
                    *args, **kwargs).compile()
        assert compiled.cost_analysis() is not None
