"""Diffusion schedule + latent action chain (paper Theorem 2)."""
import jax
import jax.numpy as jnp
import numpy as np

from _property import given, settings, st

from repro.core import networks as nets
from repro.core.diffusion import (forward_sample, make_schedule,
                                  reverse_step, run_reverse_chain)


@settings(max_examples=20, deadline=None)
@given(I=st.integers(1, 20))
def test_schedule_properties(I):
    s = make_schedule(I)
    betas = np.asarray(s.betas)
    assert ((betas > 0) & (betas < 1)).all()
    assert (np.diff(betas) >= -1e-7).all()          # monotone increasing
    lb = np.asarray(s.lambda_bars)
    assert (np.diff(lb) <= 1e-7).all()              # cumprod decreasing
    assert ((np.asarray(s.beta_tildes) >= 0)).all()


def test_forward_sample_interpolates():
    s = make_schedule(5)
    x0 = jnp.ones((4,))
    eps = jnp.zeros((4,))
    x1 = forward_sample(s, x0, 1, eps)
    x5 = forward_sample(s, x0, 5, eps)
    # signal decays with i
    assert float(jnp.abs(x5).max()) < float(jnp.abs(x1).max())


def test_reverse_step_deterministic_at_i1():
    s = make_schedule(5)
    x = jnp.array([1.0, -1.0])
    eps_pred = jnp.array([0.1, 0.2])
    big_noise = jnp.array([100.0, 100.0])
    out1 = reverse_step(s, eps_pred, x, 1, big_noise)
    out2 = reverse_step(s, eps_pred, x, 1, -big_noise)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2))


def test_paper_vs_ddpm_variance_differ():
    s = make_schedule(5)
    x = jnp.ones((3,))
    eps = jnp.zeros((3,))
    noise = jnp.ones((3,))
    a = reverse_step(s, eps, x, 3, noise, paper_variance=True)
    b = reverse_step(s, eps, x, 3, noise, paper_variance=False)
    assert float(jnp.abs(a - b).max()) > 1e-6


def test_run_reverse_chain_shapes_and_probs():
    S_DIM, A, I = 12, 6, 5
    theta = nets.init_ladn(jax.random.key(0), S_DIM, A)
    sched = make_schedule(I)
    eps_fn = lambda x, i, s: nets.apply_ladn(theta, x, i, s)  # noqa: E731
    x0, probs = run_reverse_chain(sched, eps_fn,
                                  jax.random.normal(jax.random.key(1),
                                                    (A,)),
                                  jnp.ones((S_DIM,)), jax.random.key(2))
    assert x0.shape == (A,)
    np.testing.assert_allclose(float(probs.sum()), 1.0, atol=1e-5)
    assert bool(jnp.isfinite(x0).all())


def test_latent_init_changes_outcome():
    """The latent-action strategy must actually change the produced
    decision distribution vs a Gaussian start (otherwise the paper's
    contribution would be a no-op)."""
    S_DIM, A, I = 12, 6, 5
    theta = nets.init_ladn(jax.random.key(0), S_DIM, A)
    sched = make_schedule(I)
    eps_fn = lambda x, i, s: nets.apply_ladn(theta, x, i, s)  # noqa: E731
    s = jnp.ones((S_DIM,))
    key = jax.random.key(3)
    x_latent = 3.0 * jax.nn.one_hot(2, A)       # confident prior latent
    x_noise = jax.random.normal(key, (A,))
    _, p1 = run_reverse_chain(sched, eps_fn, x_latent, s, key)
    _, p2 = run_reverse_chain(sched, eps_fn, x_noise, s, key)
    assert float(jnp.abs(p1 - p2).max()) > 1e-4


def test_chain_is_differentiable():
    S_DIM, A, I = 8, 4, 4
    theta = nets.init_ladn(jax.random.key(0), S_DIM, A)
    sched = make_schedule(I)

    def loss(th):
        eps_fn = lambda x, i, s: nets.apply_ladn(th, x, i, s)  # noqa: E731
        _, probs = run_reverse_chain(
            sched, eps_fn, jnp.ones((A,)), jnp.ones((S_DIM,)),
            jax.random.key(0))
        return (probs ** 2).sum()

    g = jax.grad(loss)(theta)
    gmax = max(float(jnp.abs(x).max())
               for x in jax.tree_util.tree_leaves(g))
    assert np.isfinite(gmax) and gmax > 0
