"""Per-architecture smoke tests (REQUIRED by the assignment).

For each of the 10 assigned architectures: instantiate the REDUCED variant
of the same family (2 layers, d_model <= 512, <= 4 experts) and run one
forward and one train step on CPU, asserting output shapes and no NaNs.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models import forward, init_params
from repro.train import optimizer as opt_lib
from repro.train.steps import make_train_step

B, S = 2, 48


def _batch(cfg, key):
    inputs = {}
    text = S
    if cfg.vision_patches:
        text = S - cfg.vision_patches
        inputs["patches"] = jax.random.normal(
            key, (B, cfg.vision_patches, cfg.vision_dim))
    if cfg.num_codebooks:
        toks = jax.random.randint(key, (B, cfg.num_codebooks, text + 1), 0,
                                  cfg.vocab_size)
        inputs["tokens"] = toks[..., :-1]
        inputs["labels"] = toks[..., 1:]
    else:
        toks = jax.random.randint(key, (B, text + 1), 0, cfg.vocab_size)
        inputs["tokens"] = toks[:, :-1]
        if cfg.vision_patches:
            lab = jnp.zeros((B, S), jnp.int32)
            lab = lab.at[:, cfg.vision_patches:].set(toks[:, 1:text + 1])
            mask = jnp.zeros((B, S))
            mask = mask.at[:, cfg.vision_patches:].set(1.0)
            inputs["labels"] = lab
            inputs["mask"] = mask
        else:
            inputs["labels"] = toks[:, 1:]
    return inputs


@pytest.fixture(scope="module")
def key():
    return jax.random.key(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch, key):
    cfg = dataclasses.replace(reduced(get_config(arch)), vision_patches=16
                              if get_config(arch).vision_patches else 0)
    inputs = _batch(cfg, key)
    out = forward(init_params(key, cfg), cfg, inputs, mode="train")
    h = out["hidden"]
    expected_seq = inputs["tokens"].shape[-1] + (cfg.vision_patches or 0)
    assert h.shape == (B, expected_seq, cfg.d_model)
    assert bool(jnp.isfinite(h).all())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch, key):
    cfg = dataclasses.replace(reduced(get_config(arch)), vision_patches=16
                              if get_config(arch).vision_patches else 0)
    params = init_params(key, cfg)
    opt_state = opt_lib.init(params)
    step = make_train_step(cfg, opt_lib.AdamWConfig(learning_rate=1e-3,
                                                    warmup_steps=1,
                                                    total_steps=10))
    batch = _batch(cfg, key)
    new_params, new_opt, metrics = step(params, opt_state, batch)
    loss = float(metrics["loss"])
    assert jnp.isfinite(loss), arch
    assert loss > 0
    # params actually moved
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        params, new_params)
    assert max(jax.tree_util.tree_leaves(moved)) > 0
    # everything stays finite
    for leaf in jax.tree_util.tree_leaves(new_params):
        assert bool(jnp.isfinite(leaf.astype(jnp.float32)).all()), arch


@pytest.mark.parametrize("arch", ["xlstm-350m", "recurrentgemma-9b",
                                  "mixtral-8x22b"])
def test_subquadratic_decode_state_is_bounded(arch, key):
    """Decode state must not grow with the logical sequence position."""
    from repro.models import init_layer_states
    cfg = reduced(get_config(arch))
    st_small = init_layer_states(cfg, 2, 64)
    st_large = init_layer_states(cfg, 2, 4096)
    sizes = lambda st: sorted(  # noqa: E731
        x.size for x in jax.tree_util.tree_leaves(st))
    assert sizes(st_small) == sizes(st_large)
