"""Scheduler agents + the Algorithm-1 episode harness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import agents as ag
from repro.core import env as envlib
from repro.core.trainer import (LEARNED, build_episode_fn, init_agents,
                                train_method)

P_SMALL = envlib.EnvParams(num_bs=4, num_slots=6, max_tasks=4)
CFG = ag.AgentConfig(train_after=30, replay_capacity=200, batch_size=16)


@pytest.mark.parametrize("method", ["lad-ts", "d2sac-ts", "sac-ts",
                                    "dqn-ts", "opt-ts", "random-ts",
                                    "local-ts"])
def test_episode_runs_and_delay_finite(method):
    key = jax.random.key(0)
    states = init_agents(method, P_SMALL, CFG, key)
    ep = envlib.sample_episode(key, P_SMALL)
    episode = jax.jit(build_episode_fn(method, P_SMALL, CFG))
    _, avg = episode(states, ep, key)
    assert np.isfinite(float(avg))
    assert float(avg) > 0


def test_opt_beats_random():
    key = jax.random.key(1)
    ep = envlib.sample_episode(key, P_SMALL)
    opt = jax.jit(build_episode_fn("opt-ts", P_SMALL, CFG))
    rnd = jax.jit(build_episode_fn("random-ts", P_SMALL, CFG))
    _, d_opt = opt(None, ep, key)
    _, d_rnd = rnd(None, ep, key)
    assert float(d_opt) < float(d_rnd)


def test_ladts_act_updates_latent_store():
    key = jax.random.key(2)
    st = ag.ladts_init(key, CFG, P_SMALL.state_dim, P_SMALL.action_dim,
                       P_SMALL.max_tasks)
    s = jnp.ones((P_SMALL.state_dim,))
    before = st.X[1]
    a, st2 = ag.ladts_act(st, CFG, s, 1, key)
    assert 0 <= int(a) < P_SMALL.action_dim
    assert float(jnp.abs(st2.X[1] - before).max()) > 0
    # other slots untouched
    np.testing.assert_array_equal(np.asarray(st.X[0]),
                                  np.asarray(st2.X[0]))


def test_ladts_update_changes_networks():
    key = jax.random.key(3)
    st = ag.ladts_init(key, CFG, P_SMALL.state_dim, P_SMALL.action_dim,
                       P_SMALL.max_tasks)
    # seed the pool with synthetic transitions
    spec = ag.transition_spec(P_SMALL.state_dim, P_SMALL.action_dim)
    for j in range(40):
        item = jax.tree_util.tree_map(
            lambda x, j=j: jnp.asarray(
                np.random.default_rng(j).standard_normal(x.shape),
                x.dtype) if x.dtype != jnp.int32
            else jnp.asarray(j % P_SMALL.action_dim, x.dtype), spec)
        st = st._replace(replay=ag.replay_add(st.replay, item, True))
    st2, metrics = ag.ladts_update(st, CFG, key)
    assert np.isfinite(float(metrics["critic_loss"]))
    assert np.isfinite(float(metrics["actor_loss"]))
    diff = max(float(jnp.abs(a - b).max()) for a, b in zip(
        jax.tree_util.tree_leaves(st.theta),
        jax.tree_util.tree_leaves(st2.theta)))
    assert diff > 0
    # s-LADN refreshed from t-LADN after update (Alg. 1 line 18)
    for a, b in zip(jax.tree_util.tree_leaves(st2.theta),
                    jax.tree_util.tree_leaves(st2.theta_act)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_learned_methods_improve_over_training():
    """After a few episodes on an easy env (one clearly-fastest ES), the
    learned scheduler must beat random."""
    p = envlib.EnvParams(num_bs=4, num_slots=8, max_tasks=6,
                         f_range=(5.0, 50.0))
    cfg = ag.AgentConfig(train_after=50, replay_capacity=500,
                         batch_size=32)
    key = jax.random.key(4)
    delays, _ = train_method("lad-ts", p, cfg, episodes=8, key=key)
    rand_delays, _ = train_method("random-ts", p, cfg, episodes=3, key=key)
    assert min(delays[-3:]) < np.mean(rand_delays) * 1.05
