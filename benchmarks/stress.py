"""Saturation stress benchmark: ramp Poisson arrival rate until goodput
collapses, per scheduler.

The closed-loop benchmark replays ONE modest trace; this harness answers
the capacity question the paper's delay claims hang on: how much offered
load can the fleet absorb before deadline goodput collapses, and which
scheduler holds the knee longest?  (The ramped-load protocol mirrors how
EAT, arXiv:2507.10026, and Two-Timescale Model Caching, arXiv:2411.01458,
evaluate edge schedulers.)

Protocol
--------
For each scheduler, the SAME geometric ladder of offered arrival rates is
replayed stage by stage (identical per-stage traces across schedulers —
same seeds), each stage on a freshly reset fleet driven with overlapped
dispatch/collect stepping.  Each stage offers load for a FIXED window
(``window_s``), so the number of arrivals scales with the stage's rate:
past fleet capacity the backlog — and with it queueing delay and
deadline misses — grows with offered load, which is what makes goodput
collapse instead of merely flattening.  The stress QoS mix carries
deadlines tightened to the benchmark's time scale (the serving defaults
of 2 s / 6 s never bite at CI token counts).  Per stage we record:

  * ``offered_rate``    — the stage's Poisson arrival rate (req/s)
  * ``throughput_rps``  — completed requests / stage wall time
  * ``goodput_rps``     — on-time completions / stage wall time (a
                          completion counts when its deadline, if any,
                          was met; best-effort completions always count)
  * ``p50_s/p95_s/p99_s`` — service-delay percentiles (completed only)
  * ``deadline_miss_rate``, ``abandoned``, ``weighted_goodput``

The SATURATION STAGE is where goodput peaks: past it, extra offered load
only converts into deadline misses and watchdog shedding, so goodput is
expected to be monotone non-increasing from there on — the invariant the
CI smoke asserts on ``BENCH_stress.json``.  ``saturation_rate`` reports
the offered rate at that knee.

An overlap A/B pair rides along: the heaviest stage replayed through
the identical fleet with ``overlap=True`` vs ``overlap=False`` cluster
stepping, recording the closed-loop wall-time speedup of dispatching all
engines before collecting any (``bench == "stress_ab"``).

Run it:  PYTHONPATH=src python -m benchmarks.run --only stress --out-dir .
"""
from __future__ import annotations

import time
from typing import List, Optional, Sequence

import numpy as np

from repro.cluster import (EdgeCluster, make_scheduler, poisson_trace,
                           summarize)
from repro.faults import RetryPolicy
from repro.serving.builders import build_fleet, warmup
from repro.workload import scaled

from benchmarks.serving import FLEET_ARCHS, bench_qos_mix


def _on_time(r) -> bool:
    return r.status == "ok" and not bool(r.missed)


def stress_qos_mix(gen_tokens: int, prompt_len: int,
                   deadlines=(0.4, 1.2)):
    """The serving QoS mix with deadlines tightened to the stress run's
    time scale (interactive, standard); batch stays deadline-free."""
    tight = {"interactive": deadlines[0], "standard": deadlines[1]}
    return tuple(
        (scaled(cls, deadline_s=tight[cls.name]) if cls.name in tight
         else cls, w)
        for cls, w in bench_qos_mix(gen_tokens, prompt_len=prompt_len))


def run_stage(engines, scheduler_name: str, *, rate: float,
              num_requests: int, prompt_len: int, gen_tokens: int,
              vocab: int, mix, seed: int, overlap: bool = True) -> dict:
    """One (scheduler, offered-rate) stage on a freshly reset fleet."""
    E = len(engines)
    for e in engines:
        e.reset()
    sched = (make_scheduler(scheduler_name, E, qos=True)
             if scheduler_name in ("failure-aware", "prefix-affinity")
             else make_scheduler(scheduler_name, E))
    cluster = EdgeCluster(engines, sched, seed=seed, qos_obs=True,
                          overlap=overlap, retry=RetryPolicy())
    trace = poisson_trace(num_requests, rate=rate, prompt_len=prompt_len,
                          max_new_tokens=gen_tokens, vocab_size=vocab,
                          num_origins=E, seed=seed, qos_mix=mix)
    t0 = time.monotonic()
    done = cluster.run(trace)
    wall = time.monotonic() - t0
    stats = summarize(done)
    on_time = sum(_on_time(r) for r in done)
    return {
        "offered_rate": float(rate),
        "wall_s": wall,
        "overlap": overlap,
        "throughput_rps": stats["completed"] / max(wall, 1e-9),
        "goodput_rps": on_time / max(wall, 1e-9),
        "on_time": int(on_time),
        **{k: stats[k] for k in ("count", "completed", "abandoned",
                                 "failed", "p50_s", "p95_s", "p99_s",
                                 "mean_s", "deadline_miss_rate",
                                 "weighted_goodput",
                                 "prefill_tokens_saved",
                                 "prefix_hit_rate")},
    }


def detect_saturation(goodputs: Sequence[float]) -> int:
    """Stage index where goodput peaks — the saturation knee.

    Past the knee, added offered load only buys deadline misses and
    shedding, so goodput must not climb again (the CI invariant)."""
    return int(np.argmax(np.asarray(goodputs, np.float64)))


def bench_stress(scale: str = "quick", n_edge: int = 4,
                 rates: Optional[Sequence[float]] = None,
                 num_requests: Optional[int] = None,
                 window_s: Optional[float] = None,
                 prompt_len: int = 16, gen_tokens: int = 6,
                 seed: int = 0, kv_slots: int = 2,
                 prefill_chunk: int = 8,
                 schedulers: Optional[Sequence[str]] = None):
    """Ramp-to-saturation stress run; returns (csv_rows, json_records).

    Each stage offers ``rate`` arrivals/s for ``window_s`` seconds, so
    stage size ``~ rate * window_s`` (clamped to [3, cap]); pass
    ``num_requests`` to pin every stage to a fixed size instead."""
    paper = scale == "paper"
    if rates is None:
        rates = ((2.0, 8.0, 32.0, 128.0, 512.0, 2048.0) if paper
                 else (8.0, 32.0, 128.0, 512.0, 2048.0))
    if window_s is None:
        window_s = 1.0 if paper else 0.35
    cap = 512 if paper else 192
    if schedulers is None:
        schedulers = (("jsq", "round-robin", "deadline", "random") if paper
                      else ("jsq", "round-robin"))

    def stage_size(rate: float) -> int:
        if num_requests is not None:
            return int(num_requests)
        return int(max(3, min(round(rate * window_s), cap)))

    mix = stress_qos_mix(gen_tokens, prompt_len,
                         deadlines=(2.0, 6.0) if paper else (0.4, 1.2))
    archs = [FLEET_ARCHS[i % len(FLEET_ARCHS)] for i in range(n_edge)]
    max_len = 3 * (prompt_len + gen_tokens)
    engines = build_fleet(archs, max_len,
                          depths=[2 + (i % 2) for i in range(n_edge)],
                          seed0=1, kv_slots=kv_slots,
                          prefill_chunk=prefill_chunk,
                          max_lanes=4 * kv_slots)
    vocab = min(e.cfg.vocab_size for e in engines)
    # warm EVERY prompt length the QoS mix can emit: dense-engine prefill
    # compiles per prompt shape, so an unwarmed length would bill its
    # compile to whichever stage first drew that class
    for plen in sorted({cls.prompt_len or prompt_len for cls, _ in mix}):
        warmup(engines, plen)

    rows: List[str] = []
    records: List[dict] = []
    for name in schedulers:
        stages = []
        for k, rate in enumerate(rates):
            n_k = stage_size(rate)
            st = run_stage(engines, name, rate=rate, num_requests=n_k,
                           prompt_len=prompt_len, gen_tokens=gen_tokens,
                           vocab=vocab, mix=mix, seed=seed + 101 * k)
            st["stage"] = k
            st["num_requests"] = n_k
            stages.append(st)
            rows.append(
                f"stress/{name}@{rate:g}rps,"
                f"{st['wall_s']/max(n_k,1)*1e6:.0f},"
                f"tput={st['throughput_rps']:.2f}rps;"
                f"goodput={st['goodput_rps']:.2f}rps;"
                f"p50={st['p50_s']:.3f}s;p95={st['p95_s']:.3f}s;"
                f"p99={st['p99_s']:.3f}s;"
                f"miss={st['deadline_miss_rate']:.2f};"
                f"shed={st['abandoned']}")
            records.append({"bench": "stress_stage", "scheduler": name,
                            "fleet": [e.arch_id for e in engines], **st})
        sat = detect_saturation([s["goodput_rps"] for s in stages])
        records.append({
            "bench": "stress_summary", "scheduler": name,
            "window_s": window_s,
            "saturation_stage": sat,
            "saturation_rate": stages[sat]["offered_rate"],
            "peak_goodput_rps": stages[sat]["goodput_rps"],
            "stages": stages,
        })
        rows.append(f"stress_summary/{name},0,"
                    f"saturation_rate={stages[sat]['offered_rate']:g}rps;"
                    f"peak_goodput={stages[sat]['goodput_rps']:.2f}rps")

    # --- overlap A/B: identical overload stage, overlapped vs serial ---
    # penultimate rung: saturated enough that stepping dominates, below
    # the cap so walls stay comparable; best-of-2 filters scheduler noise
    ab_rate = rates[-2] if len(rates) > 1 else rates[-1]
    ab = {}
    for overlap in (False, True):
        best = None
        for _ in range(2):
            st = run_stage(engines, schedulers[0], rate=ab_rate,
                           num_requests=stage_size(ab_rate),
                           prompt_len=prompt_len, gen_tokens=gen_tokens,
                           vocab=vocab, mix=mix, seed=seed + 9001,
                           overlap=overlap)
            if best is None or st["wall_s"] < best["wall_s"]:
                best = st
        ab["overlap" if overlap else "serial"] = best
    speedup = (ab["serial"]["wall_s"] / max(ab["overlap"]["wall_s"], 1e-9))
    records.append({
        "bench": "stress_ab", "scheduler": schedulers[0],
        "engines": n_edge, "offered_rate": float(ab_rate),
        "serial_wall_s": ab["serial"]["wall_s"],
        "overlap_wall_s": ab["overlap"]["wall_s"],
        "overlap_speedup": speedup,
        "serial_p95_s": ab["serial"]["p95_s"],
        "overlap_p95_s": ab["overlap"]["p95_s"],
    })
    rows.append(f"stress_ab/{schedulers[0]}@{ab_rate:g}rps,0,"
                f"serial={ab['serial']['wall_s']:.2f}s;"
                f"overlap={ab['overlap']['wall_s']:.2f}s;"
                f"speedup={speedup:.2f}x")
    return rows, records
