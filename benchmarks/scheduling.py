"""Scheduling benchmarks mirroring the paper's Figs. 5-8.

Two scales:
  quick  — shrunk env (CI-friendly, minutes): relative ordering only.
  paper  — Table III parameters (B=20, N<=50, |T|=60, 60+ episodes):
           reproduces the headline claims; results recorded in
           EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List

import jax
import numpy as np

from repro.core.agents import AgentConfig
from repro.core.diffusion import DiffusionPolicyConfig
from repro.core.env import EnvParams
from repro.core.trainer import evaluate_method, train_method


def env_for_scale(scale: str, **overrides) -> EnvParams:
    if scale == "paper":
        base = EnvParams()                      # Table III defaults
    else:
        base = EnvParams(num_bs=6, num_slots=15, max_tasks=8)
    return dataclasses.replace(base, **overrides)


def episodes_for_scale(scale: str) -> int:
    return 60 if scale == "paper" else 12


def agent_cfg(scale: str, **overrides) -> AgentConfig:
    return dataclasses.replace(
        AgentConfig(train_after=300 if scale == "paper" else 60,
                    replay_capacity=1000 if scale == "paper" else 300),
        **overrides)


def convergence_episode(delays: List[float], tol: float = 0.05) -> int:
    """First episode from which the delay stays within tol of the final
    plateau (the paper's 'converged after N episodes' metric).

    Robust to degenerate inputs: empty / single-episode curves return 0,
    and the plateau window never exceeds the curve length, so short runs
    (< 3 episodes) don't wrap the slice around."""
    arr = np.asarray(delays, dtype=np.float64)
    if arr.size == 0:
        return 0
    win = min(arr.size, max(3, arr.size // 5))
    plateau = arr[-win:].mean()
    band = tol * max(abs(plateau), 1e-12)
    for i, d in enumerate(arr):
        if abs(d - plateau) <= band and \
                (np.abs(arr[i:] - plateau) <= 3 * band).mean() > 0.7:
            return i
    return arr.size - 1


def bench_fig5_learning(scale: str, seed: int = 0) -> List[str]:
    """Fig. 5: learning curves + convergence episodes + final delay."""
    p = env_for_scale(scale)
    cfg = agent_cfg(scale)
    eps = episodes_for_scale(scale)
    rows = []
    curves: Dict[str, List[float]] = {}
    for method in ("lad-ts", "d2sac-ts", "sac-ts", "dqn-ts", "opt-ts",
                   "random-ts"):
        key = jax.random.key(seed)
        t0 = time.time()
        n_eps = eps if method in ("lad-ts", "d2sac-ts", "sac-ts",
                                  "dqn-ts") else 3
        delays, _ = train_method(method, p, cfg, episodes=n_eps, key=key)
        wall = time.time() - t0
        curves[method] = delays
        final = float(np.mean(delays[-3:]))
        conv = convergence_episode(delays) if n_eps > 5 else 0
        us = wall / max(n_eps, 1) * 1e6
        rows.append(f"fig5_learning/{method},{us:.0f},"
                    f"final_delay={final:.3f}s;converged_ep={conv}")
    return rows, curves


def bench_sweep(scale: str, param: str, values, seed: int = 1,
                methods=("lad-ts", "sac-ts", "opt-ts")) -> List[str]:
    """Figs. 6-7: delay vs an environment parameter.

    param in {max_tasks, f_hi, z_hi, num_bs}.
    """
    rows = []
    for v in values:
        over = {}
        if param == "max_tasks":
            over["max_tasks"] = int(v)
        elif param == "f_hi":
            over["f_range"] = (10.0, float(v))
        elif param == "z_hi":
            over["z_range"] = (1.0, float(v))
        elif param == "num_bs":
            over["num_bs"] = int(v)
        p = env_for_scale(scale, **over)
        cfg = agent_cfg(scale)
        eps = max(episodes_for_scale(scale) // 2, 6)
        for method in methods:
            key = jax.random.key(seed)
            t0 = time.time()
            n_eps = eps if method not in ("opt-ts", "random-ts",
                                          "local-ts") else 1
            delays, states = train_method(method, p, cfg, episodes=n_eps,
                                          key=key)
            final = evaluate_method(method, p, cfg, states,
                                    jax.random.key(seed + 1),
                                    n_episodes=2)
            us = (time.time() - t0) / max(n_eps, 1) * 1e6
            rows.append(f"sweep_{param}={v}/{method},{us:.0f},"
                        f"delay={final:.3f}s")
    return rows


def bench_fig8_params(scale: str, seed: int = 2) -> List[str]:
    """Fig. 8: denoising steps I and entropy temperature alpha."""
    p = env_for_scale(scale)
    eps = max(episodes_for_scale(scale) // 2, 6)
    rows = []
    for I in (1, 3, 5, 8):
        cfg = agent_cfg(scale,
                        diffusion=DiffusionPolicyConfig(num_steps=I))
        t0 = time.time()
        delays, states = train_method("lad-ts", p, cfg, episodes=eps,
                                      key=jax.random.key(seed))
        final = evaluate_method("lad-ts", p, cfg, states,
                                jax.random.key(seed + 1), n_episodes=2)
        us = (time.time() - t0) / eps * 1e6
        rows.append(f"fig8a_denoise_I={I}/lad-ts,{us:.0f},"
                    f"delay={final:.3f}s")
    for alpha in (0.01, 0.05, 0.2):
        cfg = agent_cfg(scale, init_alpha=alpha)
        t0 = time.time()
        delays, states = train_method("lad-ts", p, cfg, episodes=eps,
                                      key=jax.random.key(seed))
        final = evaluate_method("lad-ts", p, cfg, states,
                                jax.random.key(seed + 1), n_episodes=2)
        us = (time.time() - t0) / eps * 1e6
        rows.append(f"fig8b_alpha={alpha}/lad-ts,{us:.0f},"
                    f"delay={final:.3f}s")
    return rows
