"""Serving benchmarks on the cluster API.

``bench_tablev``       — Table-V analog: total generation delay, centralized
                         vs DEdgeAI-style distributed serving, smoke scale.
``bench_closed_loop``  — the repo's apples-to-apples "paper policy vs
                         baselines on real engines" number, now on a
                         HETEROGENEOUS fleet under a mixed-QoS workload:
                         a Poisson trace of interactive / standard /
                         batch requests replayed through engines hosting
                         DIFFERENT model-zoo configs (attention models
                         on the paged KV pool next to dense-slot xLSTM),
                         reporting per-scheduler AND per-QoS-class
                         p50/p95/p99 service delay, deadline-miss rate,
                         and priority-weighted goodput (CSV rows + JSON
                         records), plus the same schedulers evaluated in
                         the ``core.env`` simulator on the identical
                         extended Eqn-6 observation.
``bench_chaos``        — goodput under failures: the same mixed-QoS trace
                         replayed per scheduler while a deterministic
                         fault schedule crashes one engine mid-trace and
                         recovers it; reports completion rate, retries,
                         orphan-recovery latency, priority-weighted
                         goodput and the KV-accounting invariant, plus
                         the fault-enabled simulator's wrong-choice rate.
"""
from __future__ import annotations

import time
from typing import List

import jax
import numpy as np

from repro.cluster import (EdgeCluster, PolicyScheduler, evaluate_scheduler,
                           make_scheduler, poisson_trace, summarize)
from repro.configs import get_config, reduced
from repro.core.agents import AgentConfig
from repro.core.diffusion import DiffusionPolicyConfig
from repro.core.env import EnvParams
from repro.core.trainer import train_method
from repro.faults import FaultParams, RetryPolicy, single_crash
from repro.serving.builders import build_engines, build_fleet, warmup
from repro.workload import BEST_EFFORT, INTERACTIVE, STANDARD, scaled

# Default heterogeneous fleet for the closed loop: two arch families
# (attention -> paged KV pool, xLSTM -> dense slot pool) at different
# parameter scales, cycled over the edge servers.
FLEET_ARCHS = ("qwen2-1.5b", "starcoder2-3b", "xlstm-350m")


def bench_tablev(num_requests=(1, 8, 32), prompt_len: int = 16,
                 gen_tokens: int = 8, n_edge: int = 4) -> List[str]:
    """Centralized (one deep engine) vs edge cluster (n_edge shallow
    engines + JSQ placement), makespan per request count."""
    max_len = prompt_len + gen_tokens
    cloud = build_engines("qwen2-1.5b", 1, max_len, depths=[4])[0]
    edges = build_engines("qwen2-1.5b", n_edge, max_len,
                          depths=[2 + (i % 2) for i in range(n_edge)],
                          seed0=1)
    vocab = reduced(get_config("qwen2-1.5b")).vocab_size
    warmup([cloud] + edges, prompt_len)

    rows = []
    for N in num_requests:
        def trace():
            return poisson_trace(N, rate=1e6, prompt_len=prompt_len,
                                 max_new_tokens=gen_tokens,
                                 min_new_tokens=gen_tokens,
                                 vocab_size=vocab, num_origins=n_edge,
                                 seed=N)

        # centralized: every request through the single cloud engine
        cloud.reset()
        central = EdgeCluster([cloud], make_scheduler("round-robin", 1))
        t0 = time.monotonic()
        stats_c = summarize(central.run(trace()))
        wall_cloud = time.monotonic() - t0

        # distributed: queue-aware placement over the edge cluster
        for e in edges:
            e.reset()
        edge = EdgeCluster(edges, make_scheduler("jsq", n_edge))
        t0 = time.monotonic()
        stats_e = summarize(edge.run(trace()))
        wall_edge = time.monotonic() - t0

        speedup = wall_cloud / max(wall_edge, 1e-9)
        rows.append(
            f"tableV_N={N}/centralized,{wall_cloud/max(N,1)*1e6:.0f},"
            f"mean={stats_c['mean_s']:.3f}s;p95={stats_c['p95_s']:.3f}s")
        rows.append(
            f"tableV_N={N}/dedgeai,{wall_edge/max(N,1)*1e6:.0f},"
            f"mean={stats_e['mean_s']:.3f}s;p95={stats_e['p95_s']:.3f}s;"
            f"speedup={speedup:.2f}x")
    return rows


def bench_qos_mix(gen_tokens: int, prompt_len: int = 0):
    """QoS mix rescaled to the benchmark's token scale: interactive
    requests are short (half-length prompts) and prefer the smallest
    model, batch requests carry double-length prompts and run up to 3x
    the nominal generation length with no deadline.  ``prompt_len=0``
    keeps the trace-level prompt length for every class."""
    plens = {c: None for c in ("interactive", "standard", "batch")}
    if prompt_len:
        plens = {"interactive": max(prompt_len // 2, 1),
                 "standard": None,          # trace-level default
                 "batch": 2 * prompt_len}
    return ((scaled(INTERACTIVE, z_range=(1, gen_tokens),
                    prompt_len=plens["interactive"],
                    model_pref="xlstm-350m"), 0.4),
            (scaled(STANDARD,
                    z_range=(max(gen_tokens // 2, 1), 2 * gen_tokens)), 0.4),
            (scaled(BEST_EFFORT, prompt_len=plens["batch"],
                    z_range=(gen_tokens, 3 * gen_tokens)), 0.2))


def bench_closed_loop(scale: str = "quick", n_edge: int = 4,
                      num_requests: int = 24, rate: float = 96.0,
                      prompt_len: int = 32, gen_tokens: int = 8,
                      seed: int = 0, kv_slots: int = 2,
                      prefill_chunk: int = 16, page_size: int = 8,
                      prefix_len: int = -1, prefix_frac: float = 0.75):
    """Closed loop: train LAD-TS in the QoS-enabled sim, then replay one
    mixed-class Poisson trace through a HETEROGENEOUS live fleet under
    the paper policy and each baseline (including deadline-aware and the
    cache-aware prefix-affinity router).

    The fleet cycles ``FLEET_ARCHS`` over the edge servers, so paged
    attention engines and dense-slot xLSTM engines serve side by side;
    each engine queue drains in priority/EDF order.  The schedulers see
    the extended Eqn-6 observation ``[d, w, q_1..q_E, slack, c_1..c_E]``
    in BOTH backends, and every record carries the per-QoS-class
    breakdown (p50/p95/p99, deadline-miss rate, priority-weighted
    goodput).

    The trace is a shared-system-prompt mix: ``prefix_frac`` of requests
    open with one common seeded prefix (``prefix_len`` tokens, default
    3/4 of the prompt; truncated per class to its own prompt length), so
    paged engines with prefix caching serve most repeat prompts without
    re-prefilling — records report ``prefill_tokens_saved`` and
    ``prefix_hit_rate`` per scheduler.  Pass ``prefix_len=0`` for the
    legacy prefix-free trace (bit-identical behavior).

    Returns (csv_rows, json_records)."""
    paper = scale == "paper"
    if prefix_len < 0:
        prefix_len = (3 * prompt_len) // 4
    # per-class prompt lengths: interactive half-length, batch double —
    # the live fleet sees a mixed prompt-length distribution (the sim's
    # d_n spread already models it); max_len=3*(prompt+gen) below covers
    # the worst case 2*prompt_len + 3*gen_tokens
    mix = bench_qos_mix(gen_tokens, prompt_len=prompt_len)
    p = EnvParams(num_bs=n_edge, num_slots=30 if paper else 8,
                  max_tasks=12 if paper else 6, qos_mix=mix)
    acfg = AgentConfig(train_after=120 if paper else 40,
                       replay_capacity=500 if paper else 200,
                       diffusion=DiffusionPolicyConfig(
                           num_steps=5 if paper else 3))
    episodes = 20 if paper else 3
    _, states = train_method("lad-ts", p, acfg, episodes=episodes,
                             key=jax.random.key(seed))

    def scheds():
        return {
            "lad-ts": PolicyScheduler("lad-ts", acfg, states,
                                      num_engines=n_edge,
                                      n_max=p.max_tasks),
            "deadline": make_scheduler("deadline", n_edge),
            "prefix-affinity": make_scheduler("prefix-affinity", n_edge,
                                              qos=True),
            "jsq": make_scheduler("jsq", n_edge),
            "round-robin": make_scheduler("round-robin", n_edge),
            "random": make_scheduler("random", n_edge),
            "local": make_scheduler("local", n_edge),
        }

    def qos_suffix(stats):
        return (f";miss={stats.get('deadline_miss_rate', 0.0):.2f}"
                f";goodput={stats.get('weighted_goodput', 0.0):.2f}")

    rows, records = [], []
    # --- same Scheduler interface against the core.env simulator ----------
    for name, s in scheds().items():
        if getattr(s, "prefix_obs", False):
            continue   # the slot-based sim has no KV model to be warm in
        t0 = time.monotonic()
        r = evaluate_scheduler(s, p, episodes=2, key=jax.random.key(1))
        r.pop("carry", None)   # agent pytree, not JSON material
        wall = time.monotonic() - t0
        us = wall / max(r["count"], 1) * 1e6
        rows.append(f"closedloop_sim/{name},{us:.0f},"
                    f"mean={r['mean_s']:.3f}s;p95={r['p95_s']:.3f}s"
                    + qos_suffix(r))
        records.append({"bench": "closedloop_sim", "scheduler": name,
                        "wall_s": wall, **r})

    # --- and against the live heterogeneous fleet ---------------------------
    archs = [FLEET_ARCHS[i % len(FLEET_ARCHS)] for i in range(n_edge)]
    # engines are provisioned for requests up to max_len; batch-class
    # requests generate up to 3 * gen_tokens, and the paged engines keep
    # pooling whatever KV the short interactive requests leave free
    max_len = 3 * (prompt_len + gen_tokens)
    engines = build_fleet(archs, max_len,
                          depths=[2 + (i % 2) for i in range(n_edge)],
                          seed0=1, kv_slots=kv_slots,
                          prefill_chunk=prefill_chunk,
                          page_size=page_size,
                          max_lanes=4 * kv_slots)
    # one trace must tokenize for every engine in the mixed fleet
    vocab = min(e.cfg.vocab_size for e in engines)
    warmup(engines, prompt_len)
    for name, s in scheds().items():
        for e in engines:
            e.reset()             # also clears each prefix cache: every
        # scheduler starts COLD, so hit rates compare placement quality
        cluster = EdgeCluster(engines, s, seed=seed, qos_obs=True)
        trace = poisson_trace(num_requests, rate=rate,
                              prompt_len=prompt_len,
                              max_new_tokens=gen_tokens,
                              vocab_size=vocab,
                              num_origins=n_edge, seed=seed + 1,
                              qos_mix=mix, prefix_len=prefix_len,
                              prefix_frac=prefix_frac)
        t0 = time.monotonic()
        stats = summarize(cluster.run(trace))
        wall = time.monotonic() - t0
        us = wall / max(stats["count"], 1) * 1e6
        peak = max(e.peak_inflight for e in engines)
        rows.append(f"closedloop_live/{name},{us:.0f},"
                    f"mean={stats['mean_s']:.3f}s;"
                    f"p50={stats['p50_s']:.3f}s;"
                    f"p95={stats['p95_s']:.3f}s;"
                    f"p99={stats['p99_s']:.3f}s;"
                    f"peak_inflight={peak}" + qos_suffix(stats)
                    + f";saved={stats['prefill_tokens_saved']}"
                    f";hit={stats['prefix_hit_rate']:.2f}")
        records.append({
            "bench": "closedloop_live", "scheduler": name,
            "wall_s": wall,
            "throughput_rps": stats["count"] / max(wall, 1e-9),
            "fleet": [e.arch_id for e in engines],
            "paged": [bool(e.paged) for e in engines],
            "kv_slots": kv_slots,
            "prefill_chunk": prefill_chunk,
            "prompt_len": prompt_len,
            "page_size": page_size,
            "prefix_len": prefix_len,
            "prefix_frac": prefix_frac,
            "peak_inflight": peak,
            "engine_prefill_tokens_saved": [int(e.prefill_tokens_saved)
                                            for e in engines],
            "engine_prefix_hit_rate": [float(e.prefix_hit_rate)
                                       for e in engines],
            "cow_forks": int(sum(e.cow_forks for e in engines)),
            "prefix_evictions": int(sum(e.prefix_evictions
                                        for e in engines)),
            **stats})
    return rows, records


def bench_chaos(scale: str = "quick", n_edge: int = 2,
                num_requests: int = 16, rate: float = 48.0,
                prompt_len: int = 16, gen_tokens: int = 6,
                seed: int = 0, kv_slots: int = 2, prefill_chunk: int = 8,
                fault_seed: int = 0):
    """Chaos run: one hard mid-trace crash + recovery, per scheduler.

    A calibration pass (JSQ, fault-free) measures the trace makespan;
    the chaos passes then crash one engine at 0.3x that makespan and
    recover it 0.35x later, so every scheduler faces the IDENTICAL
    deterministic fault schedule (same ``fault_seed`` -> same schedule).
    Acceptance: every non-abandoned request completes (completion_rate
    == 1.0), retries stay within the policy cap, and each engine's KV
    accounting returns to zero — the crash-recovery invariants CI
    asserts on the emitted ``BENCH_chaos.json``.

    Returns (csv_rows, json_records)."""
    paper = scale == "paper"
    if paper:
        num_requests, rate = 4 * num_requests, 2 * rate
    mix = bench_qos_mix(gen_tokens, prompt_len=prompt_len)
    E = n_edge
    archs = [FLEET_ARCHS[i % len(FLEET_ARCHS)] for i in range(E)]
    max_len = 3 * (prompt_len + gen_tokens)
    engines = build_fleet(archs, max_len,
                          depths=[2 + (i % 2) for i in range(E)],
                          seed0=1, kv_slots=kv_slots,
                          prefill_chunk=prefill_chunk,
                          max_lanes=4 * kv_slots)
    vocab = min(e.cfg.vocab_size for e in engines)
    warmup(engines, prompt_len)

    def trace():
        return poisson_trace(num_requests, rate=rate,
                             prompt_len=prompt_len,
                             max_new_tokens=gen_tokens, vocab_size=vocab,
                             num_origins=E, seed=seed + 1, qos_mix=mix)

    # --- calibration: fault-free makespan anchors the fault schedule ----
    for e in engines:
        e.reset()
    t0 = time.monotonic()
    EdgeCluster(engines, make_scheduler("jsq", E), seed=seed).run(trace())
    makespan = time.monotonic() - t0
    crash_t = 0.3 * makespan
    downtime = 0.35 * makespan
    rng = np.random.default_rng(fault_seed)
    victim = int(rng.integers(E))

    rows, records = [], []
    scheds = {
        "failure-aware": make_scheduler("failure-aware", E, qos=True),
        "deadline": make_scheduler("deadline", E),
        "jsq": make_scheduler("jsq", E),
        "round-robin": make_scheduler("round-robin", E),
    }
    for name, s in scheds.items():
        for e in engines:
            e.reset()
        inj = single_crash(engine=victim, t_s=crash_t,
                           downtime_s=downtime, num_engines=E)
        cluster = EdgeCluster(engines, s, seed=seed, faults=inj,
                              retry=RetryPolicy())
        t0 = time.monotonic()
        stats = summarize(cluster.run(trace()))
        wall = time.monotonic() - t0
        fs = cluster.fault_stats
        rec_s = fs["orphan_recovery_s"]
        leak = [int(e.kv_leak) for e in engines]
        rows.append(
            f"chaos_live/{name},{wall/max(num_requests,1)*1e6:.0f},"
            f"cr={stats['completion_rate']:.3f};"
            f"completed={stats['completed']};failed={stats['failed']};"
            f"abandoned={stats['abandoned']};retries={stats['retries']};"
            f"orphans={fs['orphaned']};"
            f"goodput={stats.get('weighted_goodput', 0.0):.2f};"
            f"kv_leak={sum(leak)}")
        records.append({
            "bench": "chaos_live", "scheduler": name, "wall_s": wall,
            "makespan_calib_s": makespan,
            "goodput_rps": stats["completed"] / max(wall, 1e-9),
            "fault_schedule": inj.describe(), "fault_seed": fault_seed,
            "orphan_recovery_mean_s": (float(np.mean(rec_s))
                                       if rec_s else 0.0),
            "kv_leak": leak,
            **{k: v for k, v in fs.items() if k != "orphan_recovery_s"},
            **stats})

    # --- fault-enabled simulator twin: wrong-choice rate ----------------
    p = EnvParams(num_bs=E, num_slots=16 if paper else 8,
                  max_tasks=8 if paper else 5,
                  fault=FaultParams(p_down=0.15, p_up=0.5))
    for name in ("failure-aware", "jsq", "round-robin"):
        s = make_scheduler(name, E)
        t0 = time.monotonic()
        r = evaluate_scheduler(s, p, episodes=2, key=jax.random.key(seed))
        r.pop("carry", None)
        wall = time.monotonic() - t0
        rows.append(f"chaos_sim/{name},{wall/max(r['count'],1)*1e6:.0f},"
                    f"mean={r['mean_s']:.3f}s;"
                    f"wrong={r['wrong_choice_rate']:.3f}")
        records.append({"bench": "chaos_sim", "scheduler": name,
                        "wall_s": wall, "p_down": p.fault.p_down,
                        "p_up": p.fault.p_up, **r})
    return rows, records
