"""Serving benchmarks on the cluster API.

``bench_tablev``       — Table-V analog: total generation delay, centralized
                         vs DEdgeAI-style distributed serving, smoke scale.
``bench_closed_loop``  — the repo's first apples-to-apples "paper policy vs
                         baselines on real engines" number: a Poisson
                         arrival trace replayed through N continuous-
                         batching engines under each scheduler, reporting
                         throughput and mean / p50 / p95 / p99 service
                         delay per scheduler (CSV rows + JSON records),
                         plus the same schedulers evaluated in the
                         ``core.env`` simulator through the identical
                         interface.  The live engines serve from the
                         shared KV page pool, so the per-scheduler
                         ``peak_inflight`` exceeds what the old
                         slot-partitioned cache allowed.
"""
from __future__ import annotations

import time
from typing import List

import jax

from repro.cluster import (EdgeCluster, PolicyScheduler, evaluate_scheduler,
                           make_scheduler, poisson_trace, summarize)
from repro.configs import get_config, reduced
from repro.core.agents import AgentConfig
from repro.core.diffusion import DiffusionPolicyConfig
from repro.core.env import EnvParams
from repro.core.trainer import train_method
from repro.serving.builders import build_engines, warmup


def bench_tablev(num_requests=(1, 8, 32), prompt_len: int = 16,
                 gen_tokens: int = 8, n_edge: int = 4) -> List[str]:
    """Centralized (one deep engine) vs edge cluster (n_edge shallow
    engines + JSQ placement), makespan per request count."""
    max_len = prompt_len + gen_tokens
    cloud = build_engines("qwen2-1.5b", 1, max_len, depths=[4])[0]
    edges = build_engines("qwen2-1.5b", n_edge, max_len,
                          depths=[2 + (i % 2) for i in range(n_edge)],
                          seed0=1)
    vocab = reduced(get_config("qwen2-1.5b")).vocab_size
    warmup([cloud] + edges, prompt_len)

    rows = []
    for N in num_requests:
        def trace():
            return poisson_trace(N, rate=1e6, prompt_len=prompt_len,
                                 max_new_tokens=gen_tokens,
                                 min_new_tokens=gen_tokens,
                                 vocab_size=vocab, num_origins=n_edge,
                                 seed=N)

        # centralized: every request through the single cloud engine
        cloud.reset()
        central = EdgeCluster([cloud], make_scheduler("round-robin", 1))
        t0 = time.monotonic()
        stats_c = summarize(central.run(trace()))
        wall_cloud = time.monotonic() - t0

        # distributed: queue-aware placement over the edge cluster
        for e in edges:
            e.reset()
        edge = EdgeCluster(edges, make_scheduler("jsq", n_edge))
        t0 = time.monotonic()
        stats_e = summarize(edge.run(trace()))
        wall_edge = time.monotonic() - t0

        speedup = wall_cloud / max(wall_edge, 1e-9)
        rows.append(
            f"tableV_N={N}/centralized,{wall_cloud/max(N,1)*1e6:.0f},"
            f"mean={stats_c['mean_s']:.3f}s;p95={stats_c['p95_s']:.3f}s")
        rows.append(
            f"tableV_N={N}/dedgeai,{wall_edge/max(N,1)*1e6:.0f},"
            f"mean={stats_e['mean_s']:.3f}s;p95={stats_e['p95_s']:.3f}s;"
            f"speedup={speedup:.2f}x")
    return rows


def bench_closed_loop(scale: str = "quick", n_edge: int = 4,
                      num_requests: int = 24, rate: float = 96.0,
                      prompt_len: int = 32, gen_tokens: int = 8,
                      seed: int = 0, kv_slots: int = 2,
                      prefill_chunk: int = 16):
    """Closed loop: train LAD-TS in the sim, then replay one Poisson trace
    through the live cluster under the paper policy and each baseline.

    The live engines run the paged KV path where the config supports it:
    ``kv_slots`` sizes only the shared page-pool KV *budget*, and the
    per-scheduler ``peak_inflight`` record shows concurrency exceeding
    it (the dense engine at this budget could never hold more than
    ``kv_slots`` requests).  ``prompt_len > prefill_chunk`` forces every
    prompt through multi-chunk prefill interleaved with decode rounds.

    Returns (csv_rows, json_records)."""
    paper = scale == "paper"
    p = EnvParams(num_bs=n_edge, num_slots=30 if paper else 8,
                  max_tasks=12 if paper else 6)
    acfg = AgentConfig(train_after=120 if paper else 40,
                       replay_capacity=500 if paper else 200,
                       diffusion=DiffusionPolicyConfig(
                           num_steps=5 if paper else 3))
    episodes = 20 if paper else 3
    _, states = train_method("lad-ts", p, acfg, episodes=episodes,
                             key=jax.random.key(seed))

    def scheds():
        return {
            "lad-ts": PolicyScheduler("lad-ts", acfg, states,
                                      num_engines=n_edge,
                                      n_max=p.max_tasks),
            "jsq": make_scheduler("jsq", n_edge),
            "round-robin": make_scheduler("round-robin", n_edge),
            "random": make_scheduler("random", n_edge),
            "local": make_scheduler("local", n_edge),
        }

    rows, records = [], []
    # --- same Scheduler interface against the core.env simulator ----------
    for name, s in scheds().items():
        t0 = time.monotonic()
        r = evaluate_scheduler(s, p, episodes=2, key=jax.random.key(1))
        wall = time.monotonic() - t0
        us = wall / max(r["count"], 1) * 1e6
        rows.append(f"closedloop_sim/{name},{us:.0f},"
                    f"mean={r['mean_s']:.3f}s;p95={r['p95_s']:.3f}s")
        records.append({"bench": "closedloop_sim", "scheduler": name,
                        "wall_s": wall, **r})

    # --- and against the live engines --------------------------------------
    mcfg = reduced(get_config("qwen2-1.5b"))
    # engines are provisioned for requests up to max_len; the trace's
    # (prompt + gen) requests are smaller, so the page pool fits several
    # of them inside one dense slot's worth of KV — that headroom is
    # exactly what the slot-partitioned cache wasted
    max_len = 3 * (prompt_len + gen_tokens)
    engines = build_engines("qwen2-1.5b", n_edge, max_len,
                            depths=[2 + (i % 2) for i in range(n_edge)],
                            seed0=1, kv_slots=kv_slots,
                            prefill_chunk=prefill_chunk,
                            max_lanes=4 * kv_slots)
    warmup(engines, prompt_len)
    for name, s in scheds().items():
        for e in engines:
            e.reset()
        cluster = EdgeCluster(engines, s, seed=seed)
        trace = poisson_trace(num_requests, rate=rate,
                              prompt_len=prompt_len,
                              max_new_tokens=gen_tokens,
                              vocab_size=mcfg.vocab_size,
                              num_origins=n_edge, seed=seed + 1)
        t0 = time.monotonic()
        stats = summarize(cluster.run(trace))
        wall = time.monotonic() - t0
        us = wall / max(stats["count"], 1) * 1e6
        peak = max(e.peak_inflight for e in engines)
        rows.append(f"closedloop_live/{name},{us:.0f},"
                    f"mean={stats['mean_s']:.3f}s;"
                    f"p50={stats['p50_s']:.3f}s;"
                    f"p95={stats['p95_s']:.3f}s;"
                    f"p99={stats['p99_s']:.3f}s;"
                    f"peak_inflight={peak}")
        records.append({
            "bench": "closedloop_live", "scheduler": name,
            "wall_s": wall,
            "throughput_rps": stats["count"] / max(wall, 1e-9),
            "paged": bool(engines[0].paged),
            "kv_slots": kv_slots,
            "prefill_chunk": prefill_chunk,
            "prompt_len": prompt_len,
            "peak_inflight": peak,
            **stats})
    return rows, records
