"""Table-V analog: total generation delay, centralized vs DEdgeAI-style
distributed serving with scheduling, at smoke scale.

The paper's Table V compares wall-clock generation delay of 5 cloud
platforms vs DEdgeAI (5 Jetsons + LAD-TS) for |N| = 1..1000 requests.
Here: reduced models on CPU, a "cloud" = single fast engine with one
queue, vs an "edge cluster" = E engines with heterogeneous speeds + the
scheduler placing each request on the queue-aware best engine.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.models.transformer import init_params
from repro.serving.engine import ServeEngine


def _make_engine(arch: str, num_layers: int, seed: int,
                 max_len: int) -> ServeEngine:
    cfg = dataclasses.replace(reduced(get_config(arch)),
                              num_layers=num_layers)
    params = init_params(jax.random.key(seed), cfg)
    return ServeEngine(cfg, params, max_len=max_len)


def bench_tablev(num_requests=(1, 8, 32), prompt_len: int = 16,
                 gen_tokens: int = 8, n_edge: int = 4) -> List[str]:
    key = jax.random.key(0)
    max_len = prompt_len + gen_tokens
    # cloud: one deep (2x layers) engine; edge: n_edge shallow engines with
    # heterogeneous depth (speed proxy)
    cloud = _make_engine("qwen2-1.5b", 4, 0, max_len)
    edges = [_make_engine("qwen2-1.5b", 2 + (i % 2), i + 1, max_len)
             for i in range(n_edge)]
    vocab = reduced(get_config("qwen2-1.5b")).vocab_size

    # warm up jit compiles so makespans reflect steady-state serving
    warm = jax.random.randint(key, (1, prompt_len), 0, vocab)
    cloud.generate(warm, 1)
    for e in edges:
        e.generate(warm, 1)

    rows = []
    for N in num_requests:
        prompts = [jax.random.randint(jax.random.fold_in(key, r),
                                      (1, prompt_len), 0, vocab)
                   for r in range(N)]
        # centralized: all requests through the single cloud engine (FCFS)
        cloud._busy_until = 0.0
        t0 = time.time()
        makespan_cloud = 0.0
        for pr in prompts:
            res = cloud.generate(pr, gen_tokens)
            makespan_cloud += res.prefill_s + res.decode_s
        wall_cloud = time.time() - t0

        # distributed: queue-aware greedy placement (Opt-TS style, the
        # scheduler's serving-side role)
        for e in edges:
            e._busy_until = 0.0
        busy = [0.0] * len(edges)
        t0 = time.time()
        per_engine_time = [0.0] * len(edges)
        for pr in prompts:
            i = int(np.argmin(busy))
            res = edges[i].generate(pr, gen_tokens)
            busy[i] += res.prefill_s + res.decode_s
            per_engine_time[i] = busy[i]
        makespan_edge = max(per_engine_time) if per_engine_time else 0.0
        wall_edge = time.time() - t0

        speedup = makespan_cloud / max(makespan_edge, 1e-9)
        rows.append(
            f"tableV_N={N}/centralized,{wall_cloud/max(N,1)*1e6:.0f},"
            f"makespan={makespan_cloud:.2f}s")
        rows.append(
            f"tableV_N={N}/dedgeai,{wall_edge/max(N,1)*1e6:.0f},"
            f"makespan={makespan_edge:.2f}s;speedup={speedup:.2f}x")
    return rows
