"""Benchmark harness: one bench per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  With ``--out-dir`` the
closedloop and kernels benches additionally write machine-readable
results (``BENCH_closedloop.json`` / ``BENCH_kernels.json``: per-scheduler
throughput + p50/p95/p99 service delay, per-kernel timings with their
execution mode) for CI artifacts and cross-run comparison.

  PYTHONPATH=src python -m benchmarks.run                # quick scale
  PYTHONPATH=src python -m benchmarks.run --scale paper  # Table-III scale
  PYTHONPATH=src python -m benchmarks.run --only fig5,kernels
  PYTHONPATH=src python -m benchmarks.run --only closedloop,kernels \
      --out-dir bench_out

Mapping to the paper:
  fig5     -> Fig. 5   learning curves + convergence episodes
  fig6a    -> Fig. 6a  delay vs number of tasks
  fig6b    -> Fig. 6b  delay vs ES capacity
  fig7a    -> Fig. 7a  delay vs quality demand z
  fig7b    -> Fig. 7b  delay vs number of BSs
  fig8     -> Fig. 8   denoising steps I / entropy temperature alpha
  tablev   -> Table V  centralized vs distributed serving makespan
  closedloop -> (systems) mixed-QoS Poisson trace through a heterogeneous
              live fleet (paged + dense engines) under LAD-TS vs baselines
              incl. deadline-aware (per-class p50/p95/p99, miss rate,
              priority-weighted goodput)
  chaos    -> (systems) the same trace under fault injection: one hard
              mid-trace crash + recovery per scheduler (completion rate,
              retries, orphan-recovery latency, goodput, KV-leak check)
              plus the fault-enabled simulator's wrong-choice rates
  kernels  -> (systems) Pallas kernel microbenches
  roofline -> (systems) dry-run roofline terms per (arch x shape x mesh)
  stress   -> (systems) saturation ramp: Poisson arrival rate climbs a
              geometric ladder per scheduler until deadline goodput
              collapses (per-stage throughput/goodput + p50/p95/p99,
              saturation knee, overlap-vs-serial stepping A/B);
              writes BENCH_stress.json with --out-dir
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", choices=["quick", "paper"], default="quick")
    ap.add_argument("--only", default=None,
                    help="comma list: fig5,fig6a,fig6b,fig7a,fig7b,fig8,"
                         "tablev,closedloop,chaos,kernels,roofline,stress")
    ap.add_argument("--out-dir", default=None,
                    help="write BENCH_<name>.json result files here")
    ap.add_argument("--trajectory", action="store_true",
                    help="append a per-run summary (git sha, date, knee "
                         "goodput, p95, prefix savings) to "
                         "BENCH_trajectory.json in --out-dir — the "
                         "tracked perf trajectory across PRs")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    def want(name):
        return only is None or name in only

    all_records = {}

    def emit(name, records):
        all_records[name] = records
        if args.out_dir is None:
            return
        os.makedirs(args.out_dir, exist_ok=True)
        path = os.path.join(args.out_dir, f"BENCH_{name}.json")

        def tolist(o):   # numpy / jax scalars and arrays
            if hasattr(o, "tolist"):
                return o.tolist()
            return str(o)

        with open(path, "w") as f:
            json.dump({"bench": name, "scale": args.scale,
                       "records": records}, f, indent=2, default=tolist)
        print(f"# wrote {path}", file=sys.stderr)

    rows = []
    t0 = time.time()

    if want("fig5"):
        from benchmarks.scheduling import bench_fig5_learning
        r, _ = bench_fig5_learning(args.scale)
        rows += r
    if want("fig6a"):
        from benchmarks.scheduling import bench_sweep
        vals = (10, 30, 50, 70) if args.scale == "paper" else (4, 8, 12)
        rows += bench_sweep(args.scale, "max_tasks", vals)
    if want("fig6b"):
        from benchmarks.scheduling import bench_sweep
        vals = (30, 50, 70) if args.scale == "paper" else (20, 40)
        rows += bench_sweep(args.scale, "f_hi", vals)
    if want("fig7a"):
        from benchmarks.scheduling import bench_sweep
        vals = (5, 10, 15, 20) if args.scale == "paper" else (5, 15)
        rows += bench_sweep(args.scale, "z_hi", vals)
    if want("fig7b"):
        from benchmarks.scheduling import bench_sweep
        vals = (10, 20, 30, 40) if args.scale == "paper" else (4, 8)
        rows += bench_sweep(args.scale, "num_bs", vals)
    if want("fig8"):
        from benchmarks.scheduling import bench_fig8_params
        rows += bench_fig8_params(args.scale)
    if want("tablev"):
        from benchmarks.serving import bench_tablev
        rows += bench_tablev()
    if want("closedloop"):
        from benchmarks.serving import bench_closed_loop
        r, recs = bench_closed_loop(args.scale)
        rows += r
        emit("closedloop", recs)
    if want("chaos"):
        from benchmarks.serving import bench_chaos
        r, recs = bench_chaos(args.scale)
        rows += r
        emit("chaos", recs)
    if want("kernels"):
        from benchmarks.kernels import bench_kernels
        r, recs = bench_kernels()
        rows += r
        emit("kernels", recs)
    if want("roofline"):
        from benchmarks.roofline import bench_roofline
        rows += bench_roofline()
    if want("stress"):
        from benchmarks.stress import bench_stress
        r, recs = bench_stress(args.scale)
        rows += r
        emit("stress", recs)

    print("name,us_per_call,derived")
    for r in rows:
        print(r)
    print(f"# total bench wall time: {time.time()-t0:.1f}s",
          file=sys.stderr)

    if args.trajectory:
        if args.out_dir is None:
            ap.error("--trajectory requires --out-dir")
        append_trajectory(args.out_dir, args.scale, all_records)


def _git_sha() -> str:
    import subprocess
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or "unknown"
    except Exception:   # noqa: BLE001 — no git in the environment
        return "unknown"


def append_trajectory(out_dir: str, scale: str, all_records: dict) -> None:
    """Append this run's headline numbers to ``BENCH_trajectory.json``.

    The trajectory is the repo's perf record ACROSS commits: each entry
    carries the git sha + date and, per scheduler, the stress knee
    goodput (+ p95 at the knee) and the closed-loop mean/p95 with prefix
    cache savings — enough to spot a regression or an improvement
    between any two PRs without rerunning history.
    """
    entry = {"git_sha": _git_sha(), "scale": scale,
             "date_utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
             "benches": sorted(all_records)}
    stress = [r for r in all_records.get("stress", [])
              if r.get("bench") == "stress_stage"]
    if stress:
        knees = {}
        for name in sorted({r["scheduler"] for r in stress}):
            stages = [r for r in stress if r["scheduler"] == name]
            knee = max(stages, key=lambda r: r["goodput_rps"])
            knees[name] = {"knee_goodput_rps": knee["goodput_rps"],
                           "knee_offered_rate": knee["offered_rate"],
                           "knee_p95_s": knee["p95_s"]}
        entry["stress"] = knees
    closed = [r for r in all_records.get("closedloop", [])
              if r.get("bench") == "closedloop_live"]
    if closed:
        entry["closedloop"] = {
            r["scheduler"]: {"mean_s": r["mean_s"], "p95_s": r["p95_s"],
                             "prefill_tokens_saved":
                                 r.get("prefill_tokens_saved", 0),
                             "prefix_hit_rate":
                                 r.get("prefix_hit_rate", 0.0)}
            for r in closed}
    chaos = [r for r in all_records.get("chaos", [])
             if r.get("bench") == "chaos_live"]
    if chaos:
        entry["chaos"] = {r["scheduler"]: r["completion_rate"]
                          for r in chaos}

    path = os.path.join(out_dir, "BENCH_trajectory.json")
    doc = {"bench": "trajectory", "runs": []}
    if os.path.exists(path):
        try:
            with open(path) as f:
                prev = json.load(f)
            if isinstance(prev.get("runs"), list):
                doc = prev
        except (OSError, ValueError):
            pass            # corrupt trajectory: restart it, don't crash
    doc["runs"].append(entry)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"# appended run {entry['git_sha']} to {path} "
          f"({len(doc['runs'])} runs)", file=sys.stderr)


if __name__ == "__main__":
    main()
