"""Pallas kernel microbenchmarks (interpret mode on CPU: relative numbers
prove the fusion structure; absolute TPU timings require hardware).

The fused-LADN bench is the paper-relevant one: scheduler decision latency
is on the serving critical path (Algorithm 1 runs per task arrival).
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp

from repro.core import networks as nets
from repro.core.agents import AgentConfig
from repro.core.diffusion import make_schedule, run_reverse_chain
from repro.kernels import ops


def _time(fn, *args, reps: int = 5, **kw) -> float:
    out = fn(*args, **kw)
    jax.tree_util.tree_map(
        lambda x: x.block_until_ready()
        if hasattr(x, "block_until_ready") else x, out)
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.tree_util.tree_map(
        lambda x: x.block_until_ready()
        if hasattr(x, "block_until_ready") else x, out)
    return (time.time() - t0) / reps * 1e6  # us


def bench_kernels() -> List[str]:
    rows = []
    key = jax.random.key(0)

    # flash attention (small: interpret mode is slow)
    B, H, KV, S, hd = 1, 4, 2, 512, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, S, hd))
    k = jax.random.normal(ks[1], (B, KV, S, hd))
    v = jax.random.normal(ks[2], (B, KV, S, hd))
    us = _time(ops.flash_attention, q, k, v, bq=128, bk=128,
               interpret=True, reps=2)
    flops = 4 * B * H * S * S * hd / 2  # causal
    rows.append(f"kernel_flash_attention_S{S},{us:.0f},"
                f"causal_gflop={flops/1e9:.2f}")

    # flash decode
    kc = jax.random.normal(ks[1], (2, KV, 2048, hd))
    vc = jax.random.normal(ks[2], (2, KV, 2048, hd))
    qd = jax.random.normal(ks[0], (2, H, hd))
    us = _time(ops.flash_decode, qd, kc, vc, 2048, bk=256, interpret=True,
               reps=2)
    rows.append(f"kernel_flash_decode_S2048,{us:.0f},"
                f"cache_mb={kc.size*2*4/1e6:.1f}")

    # fused LADN chain vs unfused jnp chain (the scheduler hot loop)
    cfg = AgentConfig()
    S_DIM, A, I = 22, 20, 5
    theta = nets.init_ladn(jax.random.key(1), S_DIM, A, (20, 20))
    T = 256
    x_I = jax.random.normal(ks[0], (T, A))
    s = jax.random.normal(ks[1], (T, S_DIM))

    us_fused = _time(ops.ladn_denoise, theta, x_I, s, ks[2], num_steps=I,
                     state_dim=S_DIM, action_dim=A, interpret=True, reps=3)

    sched = make_schedule(I)

    @jax.jit
    def unfused(theta, x_I, s, key):
        eps_fn = lambda x, i, ss: nets.apply_ladn(theta, x, i, ss)  # noqa

        def one(xi, si, k):
            return run_reverse_chain(sched, eps_fn, xi, si, k)

        keys = jax.random.split(key, T)
        return jax.vmap(one)(x_I, s, keys)

    us_unfused = _time(unfused, theta, x_I, s, ks[2], reps=3)
    # NOTE: on CPU the fused kernel runs under the Pallas *interpreter*
    # while the unfused chain is XLA-compiled, so the ratio here reflects
    # interpreter overhead, not the TPU VMEM-residency win the kernel is
    # designed for (see DESIGN.md §4).
    rows.append(f"kernel_ladn_fused_T{T},{us_fused:.0f},"
                f"I={I};interpret_mode=1")
    rows.append(f"kernel_ladn_unfused_T{T},{us_unfused:.0f},"
                f"xla_compiled=1")
    return rows
