"""Pallas kernel microbenchmarks.

Each bench times the *serving-path* entry point (the ``repro.kernels.ops``
wrapper with its backend-default execution mode) rather than forcing the
Pallas interpreter: on TPU the kernels compile; off TPU ``flash_*`` fall
back to interpret mode and ``paged_flash_decode`` dispatches to its
XLA-compiled gather oracle.  Every row labels the mode actually measured
(``interpret_mode=``) so CPU numbers are never mistaken for compiled-
kernel numbers.

The fused-LADN bench is the paper-relevant one: scheduler decision latency
is on the serving critical path (Algorithm 1 runs per task arrival).
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.core import networks as nets
from repro.core.agents import AgentConfig
from repro.core.diffusion import make_schedule, run_reverse_chain
from repro.kernels import ops


def _time(fn, *args, reps: int = 5, **kw) -> float:
    out = fn(*args, **kw)
    jax.tree_util.tree_map(
        lambda x: x.block_until_ready()
        if hasattr(x, "block_until_ready") else x, out)
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.tree_util.tree_map(
        lambda x: x.block_until_ready()
        if hasattr(x, "block_until_ready") else x, out)
    return (time.time() - t0) / reps * 1e6  # us


def bench_kernels() -> Tuple[List[str], List[dict]]:
    """Returns (csv_rows, json_records)."""
    rows, records = [], []
    key = jax.random.key(0)
    # ops wrappers pick this themselves when interpret is unspecified;
    # resolve it here only to label and scale the benches honestly
    interp = jax.default_backend() != "tpu"
    mode = int(interp)

    def note(name: str, us: float, extra: str, **rec):
        rows.append(f"{name},{us:.0f},{extra};interpret_mode={mode}")
        records.append({"bench": name, "us_per_call": us,
                        "interpret_mode": bool(interp), **rec})

    # flash attention (small: interpret mode is slow)
    B, H, KV, S, hd = 1, 4, 2, 512, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, S, hd))
    k = jax.random.normal(ks[1], (B, KV, S, hd))
    v = jax.random.normal(ks[2], (B, KV, S, hd))
    us = _time(ops.flash_attention, q, k, v, bq=128, bk=128, reps=2)
    flops = 4 * B * H * S * S * hd / 2  # causal
    note(f"kernel_flash_attention_S{S}", us,
         f"causal_gflop={flops/1e9:.2f}", seq_len=S)

    # flash decode — compiled on the default backend (interpret only as
    # the off-TPU fallback the wrapper itself selects)
    Sc = 2048
    kc = jax.random.normal(ks[1], (2, KV, Sc, hd))
    vc = jax.random.normal(ks[2], (2, KV, Sc, hd))
    qd = jax.random.normal(ks[0], (2, H, hd))
    us = _time(ops.flash_decode, qd, kc, vc, Sc, bk=256, reps=2)
    note(f"kernel_flash_decode_S{Sc}", us,
         f"cache_mb={kc.size*2*4/1e6:.1f}", seq_len=Sc)

    # paged flash decode — same token count scattered across a shared
    # page pool through per-sequence block tables
    ps, npages = 64, Sc // 64
    pool = 1 + 2 * npages
    kp = jax.random.normal(ks[1], (pool, KV, ps, hd))
    vp = jax.random.normal(ks[2], (pool, KV, ps, hd))
    tbl = (1 + jax.random.permutation(jax.random.key(7), 2 * npages)
           ).reshape(2, npages).astype(jnp.int32)
    us = _time(ops.paged_flash_decode, qd, kp, vp, tbl,
               jnp.asarray([Sc, Sc // 2], jnp.int32), reps=2)
    # off TPU this wrapper runs the XLA gather oracle, not the interpreter
    pmode = "xla_ref" if interp else "0"
    rows.append(f"kernel_paged_flash_decode_S{Sc},{us:.0f},"
                f"page_size={ps};pool_pages={pool};interpret_mode={pmode}")
    records.append({"bench": f"kernel_paged_flash_decode_S{Sc}",
                    "us_per_call": us, "interpret_mode": pmode,
                    "seq_len": Sc, "page_size": ps})

    # fused LADN chain vs unfused jnp chain (the scheduler hot loop)
    cfg = AgentConfig()
    S_DIM, A, I = 22, 20, 5
    theta = nets.init_ladn(jax.random.key(1), S_DIM, A, (20, 20))
    T = 256
    x_I = jax.random.normal(ks[0], (T, A))
    s = jax.random.normal(ks[1], (T, S_DIM))

    us_fused = _time(ops.ladn_denoise, theta, x_I, s, ks[2], num_steps=I,
                     state_dim=S_DIM, action_dim=A, reps=3)

    sched = make_schedule(I)

    @jax.jit
    def unfused(theta, x_I, s, key):
        eps_fn = lambda x, i, ss: nets.apply_ladn(theta, x, i, ss)  # noqa

        def one(xi, si, k):
            return run_reverse_chain(sched, eps_fn, xi, si, k)

        keys = jax.random.split(key, T)
        return jax.vmap(one)(x_I, s, keys)

    us_unfused = _time(unfused, theta, x_I, s, ks[2], reps=3)
    # NOTE: off-TPU the fused kernel runs under the Pallas *interpreter*
    # while the unfused chain is XLA-compiled, so the ratio there reflects
    # interpreter overhead, not the TPU VMEM-residency win the kernel is
    # designed for (see DESIGN.md §4).
    note(f"kernel_ladn_fused_T{T}", us_fused, f"I={I}", tasks=T)
    rows.append(f"kernel_ladn_unfused_T{T},{us_unfused:.0f},"
                f"xla_compiled=1")
    records.append({"bench": f"kernel_ladn_unfused_T{T}",
                    "us_per_call": us_unfused, "interpret_mode": False,
                    "tasks": T})
    return rows, records
