"""Roofline summary derived from the dry-run artifacts (§Roofline).

Reads results/dryrun_*.jsonl (produced by repro.launch.dryrun --all) and
emits one CSV row per (arch x shape x mesh): the three roofline terms, the
dominant bottleneck, and the MODEL_FLOPS / HLO_FLOPs useful-compute ratio.
"""
from __future__ import annotations

import json
import os
from typing import List

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def load_records(path: str) -> List[dict]:
    if not os.path.exists(path):
        return []
    out = []
    with open(path) as f:
        for line in f:
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError:
                pass
    return out


def bench_roofline() -> List[str]:
    rows = []
    for fname, tag in (("dryrun_16x16.jsonl", "16x16"),
                       ("dryrun_2x16x16.jsonl", "2x16x16")):
        recs = load_records(os.path.join(RESULTS, fname))
        seen = {}
        for r in recs:  # keep the latest record per combo
            if "bottleneck" in r:
                seen[(r["arch"], r["shape"])] = r
        for (arch, shape), r in sorted(seen.items()):
            dom = {"compute": r["compute_s"], "memory": r["memory_s"],
                   "collective": r["collective_s"]}[r["bottleneck"]]
            us = dom * 1e6
            ratio = r.get("useful_ratio")
            rows.append(
                f"roofline_{tag}/{arch}/{shape},{us:.1f},"
                f"bottleneck={r['bottleneck']};"
                f"compute={r['compute_s']:.2e};"
                f"memory={r['memory_s']:.2e};"
                f"collective={r['collective_s']:.2e};"
                f"useful={'' if ratio is None else f'{ratio:.2f}'}")
        if not seen:
            rows.append(f"roofline_{tag}/missing,0,"
                        "run `python -m repro.launch.dryrun --all --out "
                        f"results/{fname}` first")
    return rows
