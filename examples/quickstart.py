"""Quickstart: train the LAD-TS scheduler and compare against baselines.

    PYTHONPATH=src python examples/quickstart.py [--episodes 20]

Reproduces the paper's core experiment (Fig. 5) at laptop scale: LAD-TS vs
D2SAC-TS / SAC-TS / DQN-TS / Opt-TS / Random-TS on the AIGC edge
environment, reporting final average service delay and convergence.
"""
import argparse
import sys

import jax
import numpy as np

sys.path.insert(0, "src")

from repro.core.agents import AgentConfig            # noqa: E402
from repro.core.env import EnvParams, sample_capacities  # noqa: E402
from repro.core.trainer import (evaluate_method, train_method)  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--episodes", type=int, default=20)
    ap.add_argument("--num-bs", type=int, default=8)
    ap.add_argument("--max-tasks", type=int, default=12)
    ap.add_argument("--periodicity", type=float, default=0.8)
    args = ap.parse_args()

    p = EnvParams(num_bs=args.num_bs, num_slots=30,
                  max_tasks=args.max_tasks,
                  task_periodicity=args.periodicity)
    cfg = AgentConfig(train_after=150, replay_capacity=600)
    f = sample_capacities(jax.random.key(7), p)
    print(f"edge cluster: {args.num_bs} ESs, capacities "
          f"{np.asarray(f).round(1)} Gcyc/s\n")

    results = {}
    for method in ("opt-ts", "random-ts", "local-ts"):
        delays, states = train_method(method, p, cfg, 2, jax.random.key(0),
                                      f=f)
        results[method] = (float(np.mean(delays)), "-")
        print(f"{method:10s} delay={results[method][0]:.3f}s (heuristic)")

    for method in ("lad-ts", "d2sac-ts", "sac-ts", "dqn-ts"):
        delays, states = train_method(method, p, cfg, args.episodes,
                                      jax.random.key(0), f=f, verbose=False)
        ev = evaluate_method(method, p, cfg, states, jax.random.key(1), 3,
                             f=f)
        results[method] = (ev, delays)
        print(f"{method:10s} delay={ev:.3f}s  "
              f"(train curve {['%.2f' % d for d in delays[::max(1, args.episodes//6)]]})")

    best = min((v for k, v in results.items()
                if k not in ("opt-ts",)), key=lambda kv: kv[0])
    opt = results["opt-ts"][0]
    lad = results["lad-ts"][0]
    rnd = results["random-ts"][0]
    print(f"\nLAD-TS vs Random: {(rnd-lad)/rnd*100:+.1f}% delay reduction")
    print(f"LAD-TS vs Opt gap: {(lad-opt)/opt*100:.1f}% above the "
          "full-information bound")


if __name__ == "__main__":
    main()
