"""Train a (reduced) assigned architecture for a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py --arch xlstm-350m --steps 200

Exercises the full training substrate: synthetic pipeline -> microbatched
train step (remat + chunked CE) -> AdamW + cosine schedule -> checkpoint.
Any of the 10 assigned archs works (--arch recurrentgemma-9b, dbrx-132b,
musicgen-large, ... all run as their reduced family variants).
"""
import argparse
import sys
import time

import jax

sys.path.insert(0, "src")

from repro.configs import ARCH_IDS, get_config, reduced  # noqa: E402
from repro.data.pipeline import DataConfig, synth_batch  # noqa: E402
from repro.models.transformer import init_params         # noqa: E402
from repro.train import optimizer as opt_lib              # noqa: E402
from repro.train.checkpoint import (restore_checkpoint,   # noqa: E402
                                    save_checkpoint)
from repro.train.steps import make_eval_step, make_train_step  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--ckpt", default="/tmp/repro_lm.npz")
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    if cfg.vision_patches and args.seq_len <= cfg.vision_patches:
        args.seq_len = cfg.vision_patches + 64
    dc = DataConfig(batch=args.batch, seq_len=args.seq_len)
    opt_cfg = opt_lib.AdamWConfig(learning_rate=args.lr,
                                  warmup_steps=args.steps // 10,
                                  total_steps=args.steps)

    params = init_params(jax.random.key(0), cfg)
    opt_state = opt_lib.init(params)
    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"{cfg.name}: {n/1e6:.1f}M params ({cfg.family})")

    step_fn = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))
    eval_fn = jax.jit(make_eval_step(cfg))
    t0 = time.time()
    for step in range(args.steps):
        params, opt_state, m = step_fn(params, opt_state,
                                       synth_batch(cfg, dc, step))
        if step % max(args.steps // 10, 1) == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss {float(m['loss']):7.4f} "
                  f"({(time.time()-t0):5.1f}s)")
    val = float(eval_fn(params, synth_batch(cfg, dc, 10_000)))
    print(f"held-out loss: {val:.4f}")

    save_checkpoint(args.ckpt, params, opt_state, args.steps)
    p2, o2, s2 = restore_checkpoint(args.ckpt, params, opt_state)
    print(f"checkpoint round-trip ok at step {s2}: {args.ckpt}")


if __name__ == "__main__":
    main()
