"""End-to-end DEdgeAI driver: a heterogeneous edge cluster serving real
(reduced-config) model inference through the ``repro.cluster`` API.

    PYTHONPATH=src python examples/serve_edge.py --requests 12

This is the paper's Fig. 10 worker loop at smoke scale:
  1. N_edge continuous-batching ServeEngines with different depths (speed
     heterogeneity), each running a REAL reduced transformer (per-request
     prefill + slot-pool decode with mid-flight joins).
  2. Requests arrive as a Poisson trace; the pluggable Scheduler
     (join-shortest-queue, round-robin, random, local-only — the same
     interface the trained LAD-TS policy plugs into) picks an ES each.
  3. Reported per-request delay = measured queue + prefill + decode, the
     serving-side terms of Eqn (2).
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

from repro.cluster import (EdgeCluster, make_scheduler,  # noqa: E402
                           poisson_trace, summarize)
from repro.serving.builders import build_engines, warmup  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--edges", type=int, default=4)
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=8)
    ap.add_argument("--kv-slots", type=int, default=4)
    ap.add_argument("--rate", type=float, default=8.0)
    args = ap.parse_args()

    engines = build_engines(args.arch, args.edges,
                            args.prompt_len + args.tokens,
                            kv_slots=args.kv_slots)
    vocab = engines[0].cfg.vocab_size

    # warm up compiles so timings reflect steady-state serving
    warmup(engines, args.prompt_len)

    for policy in ("jsq", "round-robin", "random", "local"):
        for e in engines:
            e.reset()
        cluster = EdgeCluster(engines, make_scheduler(policy, args.edges))
        trace = poisson_trace(args.requests, rate=args.rate,
                              prompt_len=args.prompt_len,
                              max_new_tokens=args.tokens,
                              vocab_size=vocab, num_origins=args.edges,
                              seed=42)
        t0 = time.time()
        stats = summarize(cluster.run(trace))
        print(f"{policy:12s}: mean service delay "
              f"{stats['mean_s']*1e3:7.1f} ms  "
              f"p95 {stats['p95_s']*1e3:7.1f} ms  "
              f"(n={stats['count']}, wall {time.time()-t0:.1f}s)")


if __name__ == "__main__":
    main()
