"""End-to-end DEdgeAI driver: a heterogeneous edge cluster serving real
(reduced-config) model inference, with the scheduler placing each request.

    PYTHONPATH=src python examples/serve_edge.py --requests 12

This is the paper's Fig. 10 worker loop at smoke scale:
  1. N_edge ServeEngines with different depths (speed heterogeneity),
     each running a REAL reduced transformer (prefill + decode with cache).
  2. Requests arrive in bursts; the queue-aware scheduler (the same
     decision rule LAD-TS learns towards) picks an ES per request.
  3. Reported per-request delay = queue + prefill + decode, i.e. the
     serving-side terms of Eqn (2); round-robin is the ablation.
"""
import argparse
import sys
import time

import jax
import numpy as np

sys.path.insert(0, "src")

import dataclasses                                    # noqa: E402

from repro.configs import get_config, reduced         # noqa: E402
from repro.models.transformer import init_params      # noqa: E402
from repro.serving.engine import ServeEngine          # noqa: E402


def build_cluster(n_edge, arch, prompt_len, gen_tokens):
    engines = []
    for i in range(n_edge):
        cfg = dataclasses.replace(reduced(get_config(arch)),
                                  num_layers=2 + 2 * (i % 2))
        params = init_params(jax.random.key(i), cfg)
        engines.append(ServeEngine(cfg, params,
                                   max_len=prompt_len + gen_tokens))
    return engines


def run(engines, prompts, gen_tokens, policy: str):
    for e in engines:
        e._busy_until = 0.0
    busy = np.zeros(len(engines))
    delays = []
    for i, pr in enumerate(prompts):
        if policy == "queue-aware":
            tgt = int(np.argmin(busy))
        else:  # round-robin
            tgt = i % len(engines)
        res = engines[tgt].generate(pr, gen_tokens)
        service = busy[tgt] + res.prefill_s + res.decode_s
        busy[tgt] = service
        delays.append(service)
    return float(np.mean(delays)), float(np.max(busy))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--edges", type=int, default=4)
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=8)
    args = ap.parse_args()

    engines = build_cluster(args.edges, args.arch, args.prompt_len,
                            args.tokens)
    cfg0 = engines[0].cfg
    key = jax.random.key(0)
    prompts = [jax.random.randint(jax.random.fold_in(key, r),
                                  (1, args.prompt_len), 0, cfg0.vocab_size)
               for r in range(args.requests)]

    # warm up compiles so timings reflect steady-state serving
    for e in engines:
        e.generate(prompts[0], 1)

    for policy in ("queue-aware", "round-robin"):
        t0 = time.time()
        avg, makespan = run(engines, prompts, args.tokens, policy)
        print(f"{policy:12s}: avg service delay {avg*1e3:7.1f} ms  "
              f"makespan {makespan*1e3:7.1f} ms  "
              f"(wall {time.time()-t0:.1f}s)")


if __name__ == "__main__":
    main()
