"""End-to-end DEdgeAI driver: a heterogeneous edge cluster serving real
(reduced-config) model inference through the ``repro.cluster`` API.

    PYTHONPATH=src python examples/serve_edge.py --requests 12
    PYTHONPATH=src python examples/serve_edge.py --requests 12 --qos

This is the paper's Fig. 10 worker loop at smoke scale:
  1. N_edge continuous-batching ServeEngines with different depths (speed
     heterogeneity), each running a REAL reduced transformer (per-request
     prefill + slot-pool decode with mid-flight joins).
  2. Requests arrive as a Poisson trace; the pluggable Scheduler
     (join-shortest-queue, round-robin, random, local-only — the same
     interface the trained LAD-TS policy plugs into) picks an ES each.
  3. Reported per-request delay = measured queue + prefill + decode, the
     serving-side terms of Eqn (2).

With ``--qos`` the trace mixes the default interactive / standard /
batch service classes (``repro.workload``): engines drain their queues
in priority/EDF order, the schedulers see the extended observation
(deadline slack + per-engine affinity), the deadline-aware baseline
joins the comparison, and the report adds deadline-miss rate and
priority-weighted goodput.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

from repro.cluster import (EdgeCluster, make_scheduler,  # noqa: E402
                           poisson_trace, summarize)
from repro.serving.builders import build_engines, warmup  # noqa: E402
from repro.workload import DEFAULT_MIX  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--edges", type=int, default=4)
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=8)
    ap.add_argument("--kv-slots", type=int, default=4)
    ap.add_argument("--rate", type=float, default=8.0)
    ap.add_argument("--qos", action="store_true",
                    help="mixed interactive/standard/batch QoS trace")
    args = ap.parse_args()

    qos_mix = DEFAULT_MIX if args.qos else None
    max_tokens = (max(c.z_range[1] for c, _ in DEFAULT_MIX)
                  if args.qos else args.tokens)
    engines = build_engines(args.arch, args.edges,
                            args.prompt_len + max_tokens,
                            kv_slots=args.kv_slots)
    vocab = engines[0].cfg.vocab_size

    # warm up compiles so timings reflect steady-state serving
    warmup(engines, args.prompt_len)

    policies = ("jsq", "round-robin", "random", "local")
    if args.qos:
        policies += ("deadline",)
    for policy in policies:
        for e in engines:
            e.reset()
        cluster = EdgeCluster(engines, make_scheduler(policy, args.edges),
                              qos_obs=args.qos)
        trace = poisson_trace(args.requests, rate=args.rate,
                              prompt_len=args.prompt_len,
                              max_new_tokens=args.tokens,
                              vocab_size=vocab, num_origins=args.edges,
                              seed=42, qos_mix=qos_mix)
        t0 = time.time()
        stats = summarize(cluster.run(trace))
        line = (f"{policy:12s}: mean service delay "
                f"{stats['mean_s']*1e3:7.1f} ms  "
                f"p95 {stats['p95_s']*1e3:7.1f} ms  "
                f"(n={stats['count']}, wall {time.time()-t0:.1f}s)")
        if args.qos:
            line += (f"  miss={stats['deadline_miss_rate']:.2f}"
                     f" goodput={stats['weighted_goodput']:.2f}")
        print(line)


if __name__ == "__main__":
    main()
