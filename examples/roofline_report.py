"""Print the roofline / dry-run summary from the committed artifacts.

    PYTHONPATH=src python examples/roofline_report.py [--pair dbrx-132b decode_32k]
"""
import argparse
import json
import os
import sys

sys.path.insert(0, "src")


def load(path):
    recs = {}
    if not os.path.exists(path):
        return recs
    for line in open(path):
        try:
            r = json.loads(line)
        except json.JSONDecodeError:
            continue
        if "bottleneck" in r:
            recs[(r["arch"], r["shape"])] = r
    return recs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="results")
    ap.add_argument("--pair", nargs=2, default=None,
                    metavar=("ARCH", "SHAPE"))
    args = ap.parse_args()

    single = load(os.path.join(args.results, "dryrun_16x16.jsonl"))
    multi = load(os.path.join(args.results, "dryrun_2x16x16.jsonl"))
    print(f"single-pod combos: {len(single)}; multi-pod: {len(multi)}\n")

    if args.pair:
        key = tuple(args.pair)
        for name, recs in (("16x16", single), ("2x16x16", multi)):
            r = recs.get(key)
            if not r:
                continue
            print(f"--- {key[0]} x {key[1]} on {name} "
                  f"(tag={r.get('tag')}) ---")
            print(f"  compute    {r['compute_s']:.3e} s")
            print(f"  memory     {r['memory_s']:.3e} s")
            print(f"  collective {r['collective_s']:.3e} s   "
                  f"<- bottleneck: {r['bottleneck']}")
            print(f"  useful-compute ratio {r.get('useful_ratio')}")
            print(f"  collectives by kind: "
                  f"{ {k: f'{v/1e9:.1f}GB' for k, v in r.get('collective_by_kind', {}).items()} }")
        return

    from collections import Counter
    print("bottleneck census (single-pod):",
          dict(Counter(r["bottleneck"] for r in single.values())))
    worst = sorted((r for r in single.values() if r.get("useful_ratio")),
                   key=lambda r: r["useful_ratio"])[:5]
    print("\nlowest useful-compute ratios:")
    for r in worst:
        print(f"  {r['arch']:24s} {r['shape']:12s} "
              f"useful={r['useful_ratio']:.3f} ({r['bottleneck']})")
    slowest = sorted(single.values(), key=lambda r: -max(
        r["compute_s"], r["memory_s"], r["collective_s"]))[:5]
    print("\nheaviest steps (dominant term, single-pod):")
    for r in slowest:
        dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
        print(f"  {r['arch']:24s} {r['shape']:12s} {dom:.2e}s "
              f"({r['bottleneck']})")


if __name__ == "__main__":
    main()
