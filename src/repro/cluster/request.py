"""Request lifecycle type shared by the simulator and the live engines.

A :class:`Request` is the serving-side twin of one AIGC task (paper
Eqn 2): the prompt is the uploaded input d_n, ``max_new_tokens`` is the
quality demand z_n (denoising steps / tokens to generate), and the four
timestamps decompose the measured service delay exactly:

    queue_s   = t_prefill_start - t_enqueue        (T_wait, Eqn 3)
    prefill_s = t_prefill_end   - t_prefill_start  (input compute)
    decode_s  = t_finish        - t_prefill_end    (generation compute)
    total_s   = queue_s + prefill_s + decode_s     (== t_finish - t_enqueue)

``arrival_s`` is the request's offset in a replayed trace; ``t_arrival``
is stamped by the closed-loop driver so ``service_s`` additionally counts
any scheduler-side wait before the engine ever saw the request.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: Any                       # (1, S) tokens or (1, K, S) audio
    max_new_tokens: int
    arrival_s: float = 0.0            # trace-relative arrival offset
    origin: int = 0                   # home BS / edge index
    patches: Any = None               # (1, P, D) vision patches or None

    # lifecycle (engine clock, absolute seconds) ---------------------------
    t_arrival: Optional[float] = None       # stamped by the cluster driver
    t_enqueue: Optional[float] = None       # admitted to an engine queue
    t_prefill_start: Optional[float] = None
    t_prefill_end: Optional[float] = None
    t_finish: Optional[float] = None

    engine_id: Optional[int] = None
    tokens: List[np.ndarray] = dataclasses.field(default_factory=list)

    # ------------------------------------------------------------------
    @property
    def done(self) -> bool:
        return self.t_finish is not None

    @property
    def queue_s(self) -> float:
        return self.t_prefill_start - self.t_enqueue

    @property
    def prefill_s(self) -> float:
        return self.t_prefill_end - self.t_prefill_start

    @property
    def decode_s(self) -> float:
        return self.t_finish - self.t_prefill_end

    @property
    def total_s(self) -> float:
        """Engine-side service delay (== queue_s+prefill_s+decode_s)."""
        return self.t_finish - self.t_enqueue

    @property
    def service_s(self) -> float:
        """End-to-end delay from trace arrival (falls back to total_s)."""
        t0 = self.t_arrival if self.t_arrival is not None else self.t_enqueue
        return self.t_finish - t0


def poisson_trace(num_requests: int, rate: float, prompt_len: int,
                  max_new_tokens: int, vocab_size: int, *,
                  num_origins: int = 1, min_new_tokens: int = 1,
                  num_codebooks: int = 0, seed: int = 0) -> List[Request]:
    """Poisson arrival trace with heterogeneous decode demand.

    Inter-arrival times are Exp(rate); the per-request generation length is
    U[min_new_tokens, max_new_tokens] — the z_n quality-demand analog that
    makes continuous batching matter (short requests should overtake long
    ones mid-flight).  Prompt length is fixed so one prefill compile serves
    the whole trace.
    """
    import jax
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    t = 0.0
    reqs = []
    for r in range(num_requests):
        t += float(rng.exponential(1.0 / max(rate, 1e-9)))
        shape = ((1, num_codebooks, prompt_len) if num_codebooks
                 else (1, prompt_len))
        prompt = jax.random.randint(jax.random.key(seed * 100_003 + r),
                                    shape, 0, vocab_size, jnp.int32)
        reqs.append(Request(
            rid=r, prompt=prompt,
            max_new_tokens=int(rng.integers(min_new_tokens,
                                            max_new_tokens + 1)),
            arrival_s=t,
            origin=int(rng.integers(0, num_origins))))
    return reqs


def summarize(requests: List[Request]) -> dict:
    """Mean / p50 / p95 / p99 / max service delay over completed requests."""
    delays = np.asarray([r.service_s for r in requests if r.done])
    if delays.size == 0:
        return {"count": 0, "mean_s": 0.0, "p50_s": 0.0, "p95_s": 0.0,
                "p99_s": 0.0, "max_s": 0.0}
    return {"count": int(delays.size),
            "mean_s": float(delays.mean()),
            "p50_s": float(np.percentile(delays, 50)),
            "p95_s": float(np.percentile(delays, 95)),
            "p99_s": float(np.percentile(delays, 99)),
            "max_s": float(delays.max())}
