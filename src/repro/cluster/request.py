"""Request lifecycle type shared by the simulator and the live engines.

A :class:`Request` is the serving-side twin of one AIGC task (paper
Eqn 2): the prompt is the uploaded input d_n, ``max_new_tokens`` is the
quality demand z_n (denoising steps / tokens to generate), and the four
timestamps decompose the measured service delay exactly:

    queue_s   = t_prefill_start - t_enqueue        (T_wait, Eqn 3)
    prefill_s = t_prefill_end   - t_prefill_start  (input compute)
    decode_s  = t_finish        - t_prefill_end    (generation compute)
    total_s   = queue_s + prefill_s + decode_s     (== t_finish - t_enqueue)

``arrival_s`` is the request's offset in a replayed trace; ``t_arrival``
is stamped by the closed-loop driver so ``service_s`` additionally counts
any scheduler-side wait before the engine ever saw the request.

QoS (``repro.workload``): ``qos`` carries the request's service class
(duck-typed — anything with ``name`` / ``priority`` / ``deadline_s``),
``deadline_s`` is the ABSOLUTE trace-relative deadline (``arrival_s`` +
the class budget, so deadlines are monotone with arrival inside a
class), and ``missed`` is stamped at finish time by
:meth:`Request.finish`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(eq=False)   # identity semantics: field-wise eq
class Request:                     # would compare prompt arrays
    rid: int
    prompt: Any                       # (1, S) tokens or (1, K, S) audio
    max_new_tokens: int
    arrival_s: float = 0.0            # trace-relative arrival offset
    origin: int = 0                   # home BS / edge index
    patches: Any = None               # (1, P, D) vision patches or None

    # QoS (repro.workload) -------------------------------------------------
    qos: Any = None                   # service class (QoSClass-like)
    deadline_s: Optional[float] = None  # absolute trace-relative deadline
    model_pref: Optional[str] = None  # preferred arch id
    missed: Optional[bool] = None     # stamped by finish()

    # fault tolerance (repro.faults) ---------------------------------------
    attempts: int = 0                 # placements so far (1 = first try)
    status: str = "pending"           # pending | ok | failed | abandoned
    t_orphaned: Optional[float] = None  # stamped when a crash orphans it
    fail_reason: Optional[str] = None   # last failure/abandon cause

    # lifecycle (engine clock, absolute seconds) ---------------------------
    t_arrival: Optional[float] = None       # stamped by the cluster driver
    t_enqueue: Optional[float] = None       # admitted to an engine queue
    t_prefill_start: Optional[float] = None
    t_prefill_end: Optional[float] = None
    t_finish: Optional[float] = None

    engine_id: Optional[int] = None
    tokens: List[np.ndarray] = dataclasses.field(default_factory=list)
    # prefix caching (repro.serving.paged_kv): prompt tokens whose KV was
    # reused from the engine's prefix cache on the SERVING attempt —
    # prefill compute the request never paid
    prefix_tokens: int = 0

    # ------------------------------------------------------------------
    @property
    def done(self) -> bool:
        return self.t_finish is not None

    @property
    def queue_s(self) -> float:
        return self.t_prefill_start - self.t_enqueue

    @property
    def prefill_s(self) -> float:
        return self.t_prefill_end - self.t_prefill_start

    @property
    def decode_s(self) -> float:
        return self.t_finish - self.t_prefill_end

    @property
    def total_s(self) -> float:
        """Engine-side service delay (== queue_s+prefill_s+decode_s)."""
        return self.t_finish - self.t_enqueue

    @property
    def service_s(self) -> float:
        """End-to-end delay from trace arrival (falls back to total_s)."""
        t0 = self.t_arrival if self.t_arrival is not None else self.t_enqueue
        return self.t_finish - t0

    # -- QoS helpers ---------------------------------------------------
    @property
    def priority(self) -> float:
        return float(getattr(self.qos, "priority", 1.0) or 1.0)

    @property
    def deadline_budget_s(self) -> Optional[float]:
        """Allowed service time (deadline relative to arrival)."""
        if self.deadline_s is None:
            return None
        return self.deadline_s - self.arrival_s

    @property
    def terminal(self) -> bool:
        """Request reached a final state (ok / failed / abandoned)."""
        return self.status in ("ok", "failed", "abandoned")

    def finish(self, t: float) -> None:
        """Stamp completion and resolve the deadline verdict."""
        self.t_finish = t
        self.status = "ok"
        budget = self.deadline_budget_s
        if budget is not None:
            self.missed = bool(self.service_s > budget)

    # -- fault-tolerance helpers ---------------------------------------
    def reset_for_retry(self) -> None:
        """Clear per-attempt state before a re-placement.

        Tokens and the engine-side timestamps belong to the failed
        attempt — a retried request must regenerate from scratch (no
        duplicated completions, no torn token streams).  ``t_arrival``
        survives so end-to-end delay and the watchdog keep counting from
        the ORIGINAL arrival across attempts.
        """
        self.tokens = []
        self.t_enqueue = None
        self.t_prefill_start = None
        self.t_prefill_end = None
        self.t_finish = None
        self.engine_id = None
        self.missed = None
        self.prefix_tokens = 0

    def give_up(self, status: str, reason: str) -> None:
        """Terminal failure: ``failed`` (retries exhausted) or
        ``abandoned`` (watchdog).  Leaves ``t_finish`` unset so the
        request never enters delay percentiles."""
        if status not in ("failed", "abandoned"):
            raise ValueError(f"not a terminal failure status: {status!r}")
        self.status = status
        self.fail_reason = reason


def poisson_trace(num_requests: int, rate: float, prompt_len: int,
                  max_new_tokens: int, vocab_size: int, *,
                  num_origins: int = 1, min_new_tokens: int = 1,
                  num_codebooks: int = 0, seed: int = 0,
                  qos_mix: Optional[Sequence[Tuple[Any, float]]] = None,
                  prefix_len: int = 0, prefix_frac: float = 0.0
                  ) -> List[Request]:
    """Poisson arrival trace with heterogeneous decode demand.

    Inter-arrival times are Exp(rate); the per-request generation length is
    U[min_new_tokens, max_new_tokens] — the z_n quality-demand analog that
    makes continuous batching matter (short requests should overtake long
    ones mid-flight).  Prompt length is fixed so one prefill compile serves
    the whole trace.

    With ``qos_mix`` (a sequence of ``(QoSClass, weight)`` pairs) each
    request additionally draws a service class: the generation length
    comes from the class ``z_range``, ``deadline_s`` becomes the absolute
    arrival-relative deadline (``arrival + class budget``; best-effort
    classes get none), ``model_pref`` passes through, and a per-class
    ``prompt_len`` overrides the trace-level prompt length (mixed
    prompt-length distributions).  Sampling is driven by the same seeded
    generator, so a trace is fully deterministic given ``seed``.

    With ``prefix_len > 0`` and ``prefix_frac > 0``, a deterministic
    fraction of requests share one seeded "system prompt": their first
    ``min(prefix_len, plen)`` tokens are replaced by a common prefix
    drawn once per trace — the shared-prefix workload that prefix-cached
    engines can serve without re-prefilling.  With the defaults
    (``prefix_len=0``) the generator consumes the exact same random
    stream as before, so prefix-free traces are bit-identical to
    pre-prefix behavior.
    """
    import jax
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    classes, probs = None, None
    if qos_mix:
        classes = [c for c, _ in qos_mix]
        w = np.asarray([float(x) for _, x in qos_mix], np.float64)
        if w.sum() <= 0:
            raise ValueError("qos_mix weights must sum to a positive value")
        probs = w / w.sum()
    shared = None
    if prefix_len > 0 and prefix_frac > 0:
        pshape = ((1, num_codebooks, prefix_len) if num_codebooks
                  else (1, prefix_len))
        shared = jax.random.randint(jax.random.key(seed * 77_003 + 13),
                                    pshape, 0, vocab_size, jnp.int32)
    t = 0.0
    reqs = []
    for r in range(num_requests):
        t += float(rng.exponential(1.0 / max(rate, 1e-9)))
        qos = deadline = pref = None
        plen = prompt_len
        if classes is not None:
            qos = classes[int(rng.choice(len(classes), p=probs))]
            lo, hi = qos.z_range
            new_tokens = int(rng.integers(lo, hi + 1))
            budget = float(getattr(qos, "deadline_s", math.inf))
            if math.isfinite(budget):
                deadline = t + budget
            pref = getattr(qos, "model_pref", None)
            if getattr(qos, "prompt_len", None):
                plen = int(qos.prompt_len)
        else:
            new_tokens = int(rng.integers(min_new_tokens,
                                          max_new_tokens + 1))
        shape = ((1, num_codebooks, plen) if num_codebooks
                 else (1, plen))
        prompt = jax.random.randint(jax.random.key(seed * 100_003 + r),
                                    shape, 0, vocab_size, jnp.int32)
        if shared is not None and rng.random() < prefix_frac:
            L = min(prefix_len, plen)
            prompt = jnp.concatenate(
                [shared[..., :L], prompt[..., L:]], axis=-1)
        reqs.append(Request(
            rid=r, prompt=prompt,
            max_new_tokens=new_tokens,
            arrival_s=t,
            origin=int(rng.integers(0, num_origins)),
            qos=qos, deadline_s=deadline, model_pref=pref))
    return reqs


def _delay_stats(delays: np.ndarray) -> Dict[str, float]:
    if delays.size == 0:
        return {"mean_s": 0.0, "p50_s": 0.0, "p95_s": 0.0, "p99_s": 0.0,
                "max_s": 0.0}
    return {"mean_s": float(delays.mean()),
            "p50_s": float(np.percentile(delays, 50)),
            "p95_s": float(np.percentile(delays, 95)),
            "p99_s": float(np.percentile(delays, 99)),
            "max_s": float(delays.max())}


def _is_missed(r: Request) -> bool:
    """Deadline verdict, robust to unfinished requests."""
    if r.deadline_s is None:
        return False
    if r.missed is not None:
        return bool(r.missed)
    if not r.done:
        return True          # still unfinished at summary time -> late
    budget = r.deadline_budget_s
    return bool(r.service_s > budget)


def _status_stats(reqs: Sequence[Request]) -> Dict[str, float]:
    """Terminal-status breakdown: goodput under faults, made visible.

    ``completion_rate`` is completed / non-abandoned — the chaos
    acceptance metric: watchdog-shed requests are deliberate load
    shedding, anything else must finish.  Abandoned and failed requests
    never carry a ``t_finish``, so they can never leak into the delay
    percentiles.
    """
    completed = sum(r.status == "ok" for r in reqs)
    failed = sum(r.status == "failed" for r in reqs)
    abandoned = sum(r.status == "abandoned" for r in reqs)
    non_abandoned = len(reqs) - abandoned
    return {"completed": completed, "failed": failed,
            "abandoned": abandoned,
            "retries": int(sum(max(r.attempts - 1, 0) for r in reqs)),
            "retried": sum(r.attempts > 1 for r in reqs),
            "completion_rate": (completed / non_abandoned
                                if non_abandoned else 1.0)}


def summarize(requests: Sequence[Request]) -> dict:
    """Delay percentiles + QoS + terminal-status accounting.

    Robust to an empty list and to requests that never started (or never
    finished) service: only requests with a full ``service_s`` enter the
    delay percentiles; the rest are counted in ``unfinished`` (and count
    as deadline misses when they carry one).  An ABANDONED request's
    delay is never counted into p50/p95/p99 — shedding is not serving.
    When any request has a QoS class, a per-class breakdown
    (p50/p95/p99, deadline-miss rate, priority-weighted goodput share,
    status counts) is attached under ``"classes"``.
    """
    def served(r: Request) -> bool:
        return (r.t_finish is not None
                and (r.t_arrival is not None or r.t_enqueue is not None))

    reqs = list(requests)
    done = [r for r in reqs if served(r)]
    delays = np.asarray([r.service_s for r in done], np.float64)

    out = {"count": int(delays.size),
           "unfinished": int(len(reqs) - len(done)),
           **_status_stats(reqs),
           **_delay_stats(delays)}

    # prefix-cache efficiency: prompt tokens whose prefill was skipped
    # (cache hit) and the fraction of served requests that hit at all —
    # schedulers are compared on cache efficiency, not just delay
    out["prefill_tokens_saved"] = int(
        sum(getattr(r, "prefix_tokens", 0) or 0 for r in reqs))
    out["prefix_hit_rate"] = (
        sum(1 for r in done if getattr(r, "prefix_tokens", 0)) / len(done)
        if done else 0.0)

    with_deadline = [r for r in reqs if r.deadline_s is not None]
    misses = [r for r in with_deadline if _is_missed(r)]
    out["deadline_miss_rate"] = (len(misses) / len(with_deadline)
                                 if with_deadline else 0.0)
    # priority-weighted goodput: what fraction of the offered priority
    # mass finished within its deadline (no deadline == always on time)
    w_all = sum(r.priority for r in reqs)
    w_good = sum(r.priority for r in done if not _is_missed(r))
    out["weighted_goodput"] = (w_good / w_all) if w_all > 0 else 0.0

    if any(r.qos is not None for r in reqs):
        classes: Dict[str, dict] = {}
        for name in sorted({getattr(r.qos, "name", "default")
                            for r in reqs if r.qos is not None}):
            sub = [r for r in reqs
                   if getattr(r.qos, "name", "default") == name]
            sub_done = [r for r in sub if served(r)]
            sub_delays = np.asarray([r.service_s for r in sub_done],
                                    np.float64)
            sub_dl = [r for r in sub if r.deadline_s is not None]
            sub_w = sum(r.priority for r in sub)
            sub_good = sum(r.priority for r in sub_done
                           if not _is_missed(r))
            classes[name] = {
                "count": len(sub),
                "unfinished": len(sub) - len(sub_done),
                "priority": float(sub[0].priority),
                **_status_stats(sub),
                **_delay_stats(sub_delays),
                "deadline_miss_rate": (
                    sum(_is_missed(r) for r in sub_dl) / len(sub_dl)
                    if sub_dl else 0.0),
                "weighted_goodput": (sub_good / sub_w) if sub_w else 0.0,
                "prefill_tokens_saved": int(
                    sum(getattr(r, "prefix_tokens", 0) or 0 for r in sub)),
                "prefix_hit_rate": (
                    sum(1 for r in sub_done
                        if getattr(r, "prefix_tokens", 0)) / len(sub_done)
                    if sub_done else 0.0),
            }
        out["classes"] = classes
    return out
