"""Pluggable edge-cluster schedulers: one interface, two backends.

A :class:`Scheduler` maps the paper's Eqn-6 observation rows
``s = [d_n, rho_n*z_n, q_1..q_E]`` (normalised) to target-engine indices.
Implementations are pure-JAX over an explicit ``carry`` pytree so ONE
scheduler object can drive either

  * the jitted ``repro.core.env`` episode scan (``repro.cluster.simulate``
    vectorises ``select`` over the B base stations inside ``lax.scan``), or
  * a live cluster of continuous-batching ``ServeEngine`` workers
    (``repro.cluster.live`` calls ``select_one`` per arriving request).

``PolicyScheduler`` wraps the trained LAD-TS / D2SAC-TS / SAC-TS / DQN-TS
agent states from ``repro.core.agents`` unmodified; the rest are the
non-learned baselines (round-robin, join-shortest-queue, random,
local-only) the paper ablates against.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import agents as ag
from repro.core.trainer import LEARNED, make_agent_fns

Carry = Any


class Scheduler:
    """Base: stateless-by-default scheduler over ``num_engines`` targets.

    ``state_dim`` declares the observation width the scheduler was built
    for (``None`` = shape-agnostic).  ``EdgeCluster`` validates it at
    construction: the base Eqn-6 row is ``2 + num_engines`` wide, the
    QoS-extended row ``3 + 2 * num_engines`` (see ``repro.core.env``).
    """

    name = "base"
    state_dim: Optional[int] = None

    def __init__(self, num_engines: int):
        self.num_engines = num_engines

    # -- JAX-traceable batch interface (one row per origin BS) -------------
    def init_carry(self) -> Carry:
        return jnp.zeros((), jnp.int32)

    def select(self, carry: Carry, s: jnp.ndarray, n, key
               ) -> Tuple[jnp.ndarray, Carry]:
        """s (B, state_dim) -> ((B,) int32 engine indices, carry)."""
        raise NotImplementedError

    # -- per-request interface for the live cluster ------------------------
    def select_one(self, carry: Carry, s_row: jnp.ndarray, origin: int,
                   n: int, key) -> Tuple[int, Carry]:
        a, carry = self.select(carry, s_row[None, :], n, key)
        return int(a[0]), carry

    def select_one_masked(self, carry: Carry, s_row: jnp.ndarray,
                          origin: int, n: int, key,
                          avail) -> Tuple[int, Carry]:
        """Availability-masked selection for the live cluster.

        Default policy: take the scheduler's unmasked pick; if it landed
        on a DOWN engine, redirect to the least-loaded available one
        (reading the queue features at obs columns ``2:2+E``) — the live
        twin of the simulator's ``repro.faults.mask_actions``.  The
        caller guarantees at least one engine is available.
        """
        eng, carry = self.select_one(carry, s_row, origin, n, key)
        avail = np.asarray(avail, bool)
        if avail[eng]:
            return eng, carry
        q = np.asarray(s_row, np.float32)[2:2 + self.num_engines]
        return int(np.argmin(np.where(avail, q, np.inf))), carry


class RoundRobinScheduler(Scheduler):
    name = "round-robin"

    def select(self, carry, s, n, key):
        B = s.shape[0]
        a = (carry + jnp.arange(B)) % self.num_engines
        return a.astype(jnp.int32), carry + B


class RandomScheduler(Scheduler):
    name = "random"

    def select(self, carry, s, n, key):
        a = jax.random.randint(key, (s.shape[0],), 0, self.num_engines)
        return a.astype(jnp.int32), carry


class JoinShortestQueueScheduler(Scheduler):
    """Pick the engine with the smallest queue feature (obs columns 2:)."""

    name = "jsq"

    def select(self, carry, s, n, key):
        q = s[:, 2:2 + self.num_engines]
        return jnp.argmin(q, axis=-1).astype(jnp.int32), carry


class LocalOnlyScheduler(Scheduler):
    """Every BS keeps its tasks (no offloading) — the paper's Local-TS."""

    name = "local"

    def select(self, carry, s, n, key):
        return (jnp.arange(s.shape[0]) % self.num_engines).astype(jnp.int32), \
            carry

    def select_one(self, carry, s_row, origin, n, key):
        return int(origin) % self.num_engines, carry


class DeadlineAwareScheduler(Scheduler):
    """Earliest-expected-completion placement on the QoS observation.

    Requires the extended row ``[d, w, q_1..q_E, slack, c_1..c_E]``:
    picks the engine minimising backlog + this task's own expected
    compute there (``q_e + c_e``) — JSQ that actually accounts for
    heterogeneous model/engine speed.  Per-request deadline URGENCY is
    handled where it belongs, in the engines' priority/EDF queues; this
    placement rule maximises the chance the slack survives the queue.
    """

    name = "deadline"

    def __init__(self, num_engines: int):
        super().__init__(num_engines)
        self.state_dim = 3 + 2 * num_engines

    def select(self, carry, s, n, key):
        E = self.num_engines
        q = s[:, 2:2 + E]
        aff = s[:, 3 + E:3 + 2 * E]
        return jnp.argmin(q + aff, axis=-1).astype(jnp.int32), carry


class FailureAwareScheduler(Scheduler):
    """Availability-masked least-work placement on a fault-extended row.

    Requires fault observation: the row's trailing ``E`` columns are the
    per-engine availability features (1 healthy / 0.5 degraded / 0 down)
    appended by ``EdgeCluster.observe`` and by the fault-enabled
    ``core.env`` scan.  Placement is JSQ over the AVAILABLE engines —
    plus this task's expected compute there when the QoS affinity
    columns are present — and DOWN engines are hard-masked, so it never
    pays the simulator's wrong-choice penalty and never strands a live
    request on a dead server.  DEGRADED engines stay eligible but their
    0.5 availability halves their attractiveness via a load inflation.
    """

    name = "failure-aware"

    def __init__(self, num_engines: int, qos: bool = False):
        super().__init__(num_engines)
        self.qos = bool(qos)
        base = 3 + 2 * num_engines if qos else 2 + num_engines
        self.state_dim = base + num_engines

    def select(self, carry, s, n, key):
        E = self.num_engines
        cost = s[:, 2:2 + E]
        if self.qos:
            cost = cost + s[:, 3 + E:3 + 2 * E]
        avail = s[:, -E:]
        # degraded (0.5) engines serve at reduced rate: scale their cost
        cost = cost / jnp.maximum(avail, 0.5)
        cost = jnp.where(avail > 0.25, cost, jnp.inf)
        # all-down column of inf -> argmin returns 0; the live cluster
        # never reaches that case (submit refuses on a total outage)
        return jnp.argmin(cost, axis=-1).astype(jnp.int32), carry


class PrefixAffinityScheduler(Scheduler):
    """Cache-aware placement: route where the prompt's KV already lives.

    Declares ``prefix_obs = True``, so ``EdgeCluster.observe`` appends a
    trailing per-engine block of EXPECTED PREFIX HITS — how many of this
    request's prompt tokens each engine could serve straight from its
    prefix cache (a pure peek; dense engines report 0).  Placement then
    minimises backlog minus a cache credit::

        cost_e = q_e [+ c_e with qos=True] - hit_weight * hit_e

    i.e. earliest-expected-completion where compute ALREADY DONE at an
    engine counts as negative work — the paper's "finish fastest" rule
    once resident state is part of an engine's effective speed.  The
    credit concentrates same-prefix requests on warm engines (raising
    their hit rate further), while the backlog term keeps a hot prefix
    from melting one engine.  With ``fault=True`` the availability
    columns (just before the hit block) mask DOWN engines exactly like
    ``failure-aware``.
    """

    name = "prefix-affinity"
    prefix_obs = True

    def __init__(self, num_engines: int, qos: bool = False,
                 fault: bool = False, hit_weight: float = 0.5):
        super().__init__(num_engines)
        self.qos = bool(qos)
        self.fault = bool(fault)
        self.hit_weight = float(hit_weight)
        base = 3 + 2 * num_engines if self.qos else 2 + num_engines
        self.state_dim = (base + (num_engines if self.fault else 0)
                          + num_engines)

    def select(self, carry, s, n, key):
        E = self.num_engines
        cost = s[:, 2:2 + E]
        if self.qos:
            cost = cost + s[:, 3 + E:3 + 2 * E]
        hit = s[:, -E:]
        if self.fault:
            avail = s[:, -2 * E:-E]
            cost = cost / jnp.maximum(avail, 0.5)
            cost = jnp.where(avail > 0.25, cost, jnp.inf)
        cost = cost - self.hit_weight * hit
        return jnp.argmin(cost, axis=-1).astype(jnp.int32), carry


def _infer_state_dim(states) -> Optional[int]:
    """Observation width a stacked agent pytree was trained on (the
    second-to-last axis of the first critic/Q layer's weights)."""
    for attr in ("c1", "q"):
        net = getattr(states, attr, None)
        if net is not None:
            return int(net[0]["w"].shape[-2])
    return None


class PolicyScheduler(Scheduler):
    """Trained ``repro.core.agents`` policy behind the Scheduler interface.

    ``states`` is the per-BS *stacked* agent pytree exactly as returned by
    ``repro.core.trainer.train_method`` — one agent per origin BS, vmapped
    for batch decisions (the paper's distributed deployment).  The latent
    action store (LAD-TS) keeps evolving inside the carry, so serving
    decisions keep self-conditioning the diffusion chain.
    """

    def __init__(self, method: str, cfg: ag.AgentConfig, states,
                 num_engines: int, n_max: int, greedy: bool = False):
        if method not in LEARNED:
            raise ValueError(f"{method!r} is not a learned method")
        super().__init__(num_engines)
        self.name = method
        self.method = method
        self.cfg = cfg
        self.states = states
        self.n_max = int(n_max)
        self.greedy = greedy
        self.state_dim = _infer_state_dim(states)
        _, act, _, _, _ = make_agent_fns(method, cfg)
        self._act = act
        self._vact = jax.vmap(act, in_axes=(0, 0, None, 0, None))
        self._sel1 = None

    def init_carry(self):
        return self.states

    def select(self, carry, s, n, key):
        keys = jax.random.split(key, s.shape[0])
        a, _, carry = self._vact(carry, s, n % self.n_max, keys, self.greedy)
        return (a % self.num_engines).astype(jnp.int32), carry

    def select_one(self, carry, s_row, origin, n, key):
        if self._sel1 is None:
            greedy = self.greedy

            def sel1(carry, s_row, origin, n, key):
                st = jax.tree_util.tree_map(lambda x: x[origin], carry)
                a, _, st = self._act(st, s_row, n, key, greedy)
                carry = jax.tree_util.tree_map(
                    lambda full, one: full.at[origin].set(one), carry, st)
                return (a % self.num_engines).astype(jnp.int32), carry

            self._sel1 = jax.jit(sel1)
        a, carry = self._sel1(carry, s_row, jnp.int32(origin),
                              jnp.int32(n % self.n_max), key)
        return int(a), carry


BASELINES = ("round-robin", "jsq", "random", "local", "deadline",
             "failure-aware", "prefix-affinity")


def make_scheduler(name: str, num_engines: int, **policy_kwargs) -> Scheduler:
    """Factory: baseline by name, or a learned method given agent states.

    ``failure-aware`` accepts ``qos=True`` to read the QoS-extended row;
    ``prefix-affinity`` additionally accepts ``fault=True`` and
    ``hit_weight=`` (cache-credit strength).
    """
    if name == "round-robin":
        return RoundRobinScheduler(num_engines)
    if name == "jsq":
        return JoinShortestQueueScheduler(num_engines)
    if name == "random":
        return RandomScheduler(num_engines)
    if name == "local":
        return LocalOnlyScheduler(num_engines)
    if name == "deadline":
        return DeadlineAwareScheduler(num_engines)
    if name == "failure-aware":
        return FailureAwareScheduler(num_engines,
                                     qos=policy_kwargs.pop("qos", False))
    if name == "prefix-affinity":
        return PrefixAffinityScheduler(
            num_engines, qos=policy_kwargs.pop("qos", False),
            fault=policy_kwargs.pop("fault", False),
            hit_weight=policy_kwargs.pop("hit_weight", 0.5))
    if name in LEARNED:
        return PolicyScheduler(name, num_engines=num_engines,
                               **policy_kwargs)
    raise ValueError(f"unknown scheduler {name!r}; options: "
                     f"{BASELINES + LEARNED}")
