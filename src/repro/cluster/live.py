"""Closed-loop edge cluster: a Scheduler placing requests on live engines.

``EdgeCluster`` is the serving twin of the ``repro.core.env`` simulator:
the same Scheduler object (same carry, same trained weights) that drives
the jitted episode scan here sees MEASURED per-engine backlogs and places
real requests onto continuous-batching ``ServeEngine`` workers.

The observation handed to the scheduler mirrors Eqn (6):
``[d_n, workload_n, q_1..q_E]`` with d_n = prompt tokens, workload_n =
requested generation length (the z_n quality demand), and q_e = engine
backlog in pending tokens — each divided by a fixed scale so live features
land in the same O(1) range the policies trained on.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster.request import Request
from repro.cluster.schedulers import Scheduler


@dataclasses.dataclass(frozen=True)
class LiveObsConfig:
    """Feature scales mapping token counts into the sim's O(1) obs range."""

    d_scale: float = 32.0      # prompt tokens
    w_scale: float = 16.0      # decode-token demand
    q_scale: float = 64.0      # backlog tokens


class EdgeCluster:
    """N engines + one scheduler, driven as a closed loop."""

    def __init__(self, engines: Sequence, scheduler: Scheduler,
                 obs: Optional[LiveObsConfig] = None, seed: int = 0,
                 clock: Callable[[], float] = time.monotonic):
        if scheduler.num_engines != len(engines):
            raise ValueError(
                f"scheduler targets {scheduler.num_engines} engines, "
                f"cluster has {len(engines)}")
        self.engines = list(engines)
        for i, e in enumerate(self.engines):
            e.engine_id = i
        self.scheduler = scheduler
        self.obs = obs or LiveObsConfig()
        self.carry = scheduler.init_carry()
        self._key = jax.random.key(seed)
        self._count = 0
        self._clock = clock
        self.n_max = int(getattr(scheduler, "n_max", 1))

    # ------------------------------------------------------------------
    def observe(self, req: Request) -> jnp.ndarray:
        """Eqn-6 style observation row for one arriving request."""
        q = np.asarray([e.pending_tokens for e in self.engines], np.float32)
        prompt_len = req.prompt.shape[-1]
        s = np.concatenate([
            np.asarray([prompt_len / self.obs.d_scale,
                        req.max_new_tokens / self.obs.w_scale], np.float32),
            q / self.obs.q_scale])
        return jnp.asarray(s)

    def submit(self, req: Request) -> int:
        """Scheduler picks an engine; the request joins its queue."""
        s = self.observe(req)
        self._key, k = jax.random.split(self._key)
        n = self._count % self.n_max
        eng, self.carry = self.scheduler.select_one(
            self.carry, s, req.origin, n, k)
        self._count += 1
        self.engines[eng].admit(req)
        return eng

    def step(self) -> List[Request]:
        done = []
        for e in self.engines:
            done += e.step()
        return done

    @property
    def busy(self) -> bool:
        return any(e.has_work for e in self.engines)

    # ------------------------------------------------------------------
    def run(self, trace: Sequence[Request], max_steps: int = 1_000_000
            ) -> List[Request]:
        """Replay an arrival trace in real time; returns finished requests.

        Requests become visible to the scheduler when the wall clock
        reaches their ``arrival_s``; ``service_s`` then measures the full
        arrival-to-finish delay (Eqn 2's serving-side terms).
        """
        todo = sorted(trace, key=lambda r: r.arrival_s)
        done: List[Request] = []
        i = 0
        # warm the scheduler's compiled select path outside the timed loop
        # (carry deliberately discarded: no counter/latent side effects)
        self.scheduler.select_one(
            self.carry, jnp.zeros((2 + len(self.engines),), jnp.float32),
            0, 0, jax.random.key(0))
        t0 = self._clock()
        for _ in range(max_steps):
            if i >= len(todo) and not self.busy:
                break
            now = self._clock() - t0
            while i < len(todo) and todo[i].arrival_s <= now:
                todo[i].t_arrival = t0 + todo[i].arrival_s
                self.submit(todo[i])
                i += 1
            if self.busy:
                done += self.step()
            elif i < len(todo):
                time.sleep(min(0.002,
                               max(todo[i].arrival_s - now, 0.0)))
        return done
