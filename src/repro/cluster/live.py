"""Closed-loop edge cluster: a Scheduler placing requests on live engines.

``EdgeCluster`` is the serving twin of the ``repro.core.env`` simulator:
the same Scheduler object (same carry, same trained weights) that drives
the jitted episode scan here sees MEASURED per-engine backlogs and places
real requests onto continuous-batching ``ServeEngine`` workers.

The observation handed to the scheduler mirrors Eqn (6):
``[d_n, workload_n, q_1..q_E]`` with d_n = prompt tokens, workload_n =
requested generation length (the z_n quality demand), and q_e = engine
backlog in pending tokens — each divided by a fixed scale so live
features land in the same O(1) range the policies trained on.

QoS-extended observation (``repro.workload``): when the scheduler was
built for the wider ``[.., slack, c_1..c_E]`` row, the cluster appends
the request's remaining deadline budget and a per-engine model-affinity
feature — the request's expected decode seconds on each engine, from the
engine's measured per-token rate (its live f_b'), inflated by
``pref_penalty`` on engines whose arch differs from the request's
``model_pref``.  The observation width is validated at CONSTRUCTION time
against ``scheduler.state_dim``, so a policy trained on the wrong
``EnvParams`` fails with a clear message instead of inside jit.

Fault tolerance (``repro.faults``): the cluster survives its engines.
A :class:`~repro.faults.FaultInjector` drives scheduled crash / stall /
slowdown / recovery transitions on the run-relative clock, and any
exception escaping one engine's ``step()`` QUARANTINES that engine
(marked DOWN, KV reclaimed) instead of unwinding the whole closed loop.
Requests orphaned by a crash — and everything still queued behind them —
are re-offloaded through the scheduler with capped retries and
exponential backoff; a per-request watchdog abandons requests whose
deadline is hopeless, so overload sheds the starving best-effort tail
instead of collapsing.  When fault observation is on, ``observe()``
appends a NaN-guarded per-engine availability column (1 healthy /
0.5 degraded / 0 down) so the same trained policy runs failure-aware in
sim and live, and ``submit()`` masks selection away from DOWN engines.
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Callable, Iterable, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster.request import Request
from repro.cluster.schedulers import Scheduler
from repro.faults import FaultEvent, FaultInjector, RetryPolicy


@dataclasses.dataclass(frozen=True)
class LiveObsConfig:
    """Feature scales mapping live measurements into the sim's O(1) range."""

    d_scale: float = 32.0      # prompt tokens
    w_scale: float = 16.0      # decode-token demand
    q_scale: float = 64.0      # backlog tokens
    # QoS-extended features
    slack_scale: float = 4.0   # seconds of remaining deadline budget
    slack_cap: float = 16.0    # best-effort requests report this slack
    c_scale: float = 1.0       # expected decode seconds on an engine
    pref_penalty: float = 4.0  # affinity inflation off the preferred arch
    # prefix-extended feature
    hit_scale: float = 32.0    # expected reusable prompt tokens per engine


class EdgeCluster:
    """N engines + one scheduler, driven as a closed loop."""

    def __init__(self, engines: Sequence, scheduler: Scheduler,
                 obs: Optional[LiveObsConfig] = None, seed: int = 0,
                 clock: Callable[[], float] = time.monotonic,
                 qos_obs: Optional[bool] = None,
                 faults: Union[FaultInjector, Iterable[FaultEvent],
                               None] = None,
                 retry: Optional[RetryPolicy] = None,
                 fault_obs: Optional[bool] = None,
                 prefix_obs: Optional[bool] = None,
                 overlap: bool = True):
        self.overlap = bool(overlap)
        if scheduler.num_engines != len(engines):
            raise ValueError(
                f"scheduler targets {scheduler.num_engines} engines, "
                f"cluster has {len(engines)}")
        self.engines = list(engines)
        for i, e in enumerate(self.engines):
            e.engine_id = i
        self.scheduler = scheduler
        self.obs = obs or LiveObsConfig()
        E = len(self.engines)

        # fault machinery ------------------------------------------------
        if faults is not None and not isinstance(faults, FaultInjector):
            faults = FaultInjector(list(faults), num_engines=E)
        self.injector: Optional[FaultInjector] = faults
        # the watchdog sheds only when the fault layer was asked for —
        # a fault-free cluster must behave exactly like the pre-fault one
        self._watchdog = faults is not None or retry is not None
        self.retry = retry or RetryPolicy()
        self._retry_q: List = []       # (ready_t, seq, Request) heap
        self._retry_seq = 0
        self._t0: Optional[float] = None   # run-relative fault clock epoch
        self.fault_stats = {"injected": 0, "quarantined": 0,
                            "orphaned": 0, "retries": 0, "failed": 0,
                            "abandoned": 0, "orphan_recovery_s": []}

        # observation width: (QoS, fault) feature combinations, plus an
        # optional per-engine expected-prefix-hit block appended LAST
        # (declared by the scheduler's ``prefix_obs`` class attribute)
        base_dim, qos_dim = 2 + E, 3 + 2 * E
        sched_dim = getattr(scheduler, "state_dim", None)
        if prefix_obs is None:
            prefix_obs = bool(getattr(scheduler, "prefix_obs", False))
        self.prefix_obs = bool(prefix_obs)
        # infer the QoS/fault layout from the width NET of the prefix block
        eff_dim = (sched_dim - E if (sched_dim is not None
                                     and self.prefix_obs) else sched_dim)
        if qos_obs is None:
            qos_obs = eff_dim in (qos_dim, qos_dim + E)
        self.qos_obs = bool(qos_obs)
        if fault_obs is None:
            fault_obs = (eff_dim in (base_dim + E, qos_dim + E)
                         if eff_dim is not None
                         else self.injector is not None)
        self.fault_obs = bool(fault_obs)
        self.obs_dim = ((qos_dim if self.qos_obs else base_dim)
                        + (E if self.fault_obs else 0)
                        + (E if self.prefix_obs else 0))
        if sched_dim is not None and sched_dim != self.obs_dim:
            raise ValueError(
                f"scheduler {scheduler.name!r} expects state_dim="
                f"{sched_dim}, but this {E}-engine cluster produces "
                f"{self.obs_dim}-feature observations "
                f"({'QoS-extended 3+2E' if self.qos_obs else 'base 2+E'}"
                f"{' + E availability' if self.fault_obs else ''}"
                f"{' + E prefix-hit' if self.prefix_obs else ''}; "
                f"base={base_dim}, extended={qos_dim}, +faults adds "
                f"{E}, +prefix adds {E}).  Train the policy on an "
                f"EnvParams with num_bs={E} and matching qos_mix / fault "
                f"settings, or pass qos_obs= / fault_obs= / prefix_obs= "
                f"explicitly.")
        self.carry = scheduler.init_carry()
        self._key = jax.random.key(seed)
        self._count = 0
        self._clock = clock
        self.n_max = int(getattr(scheduler, "n_max", 1))

    # ------------------------------------------------------------------
    def observe(self, req: Request) -> jnp.ndarray:
        """Eqn-6 style observation row for one arriving request."""
        q = np.asarray([e.pending_tokens for e in self.engines], np.float32)
        prompt_len = req.prompt.shape[-1]
        cols = [np.asarray([prompt_len / self.obs.d_scale,
                            req.max_new_tokens / self.obs.w_scale],
                           np.float32),
                q / self.obs.q_scale]
        if self.qos_obs:
            budget = req.deadline_budget_s
            if budget is None:
                slack = self.obs.slack_cap
            else:
                elapsed = (0.0 if req.t_arrival is None
                           else self._clock() - req.t_arrival)
                slack = min(budget - elapsed, self.obs.slack_cap)
            aff = np.asarray([req.max_new_tokens * e.est_token_seconds
                              for e in self.engines], np.float32)
            if req.model_pref is not None:
                mismatch = np.asarray(
                    [getattr(e, "arch_id", None) != req.model_pref
                     for e in self.engines])
                aff = np.where(mismatch, aff * self.obs.pref_penalty, aff)
            cols.append(np.asarray([slack / self.obs.slack_scale],
                                   np.float32))
            cols.append(aff / self.obs.c_scale)
        if self.fault_obs:
            cols.append(np.asarray([e.availability for e in self.engines],
                                   np.float32))
        if self.prefix_obs:
            # expected reusable prompt tokens per engine RIGHT NOW — a
            # pure peek against each engine's prefix index; dense /
            # cache-off engines report 0
            hit = np.asarray(
                [getattr(e, "expected_prefix_tokens", lambda r: 0)(req)
                 for e in self.engines], np.float32)
            cols.append(hit / self.obs.hit_scale)
        # NaN-guard: a crashed engine mid-measurement must never poison
        # the policy input (inf backlog estimates, NaN EWMA rates)
        row = np.nan_to_num(np.concatenate(cols), nan=0.0,
                            posinf=np.finfo(np.float32).max / 2,
                            neginf=0.0)
        return jnp.asarray(row)

    def submit(self, req: Request) -> int:
        """Scheduler picks an AVAILABLE engine; the request joins its
        queue.  Raises when every engine is DOWN — admitting into a dead
        engine would silently strand the request."""
        avail = np.asarray([e.available for e in self.engines], bool)
        if not avail.any():
            raise RuntimeError(
                f"cannot place request {req.rid}: all "
                f"{len(self.engines)} engines are DOWN "
                f"({[e.fail_reason for e in self.engines]})")
        if req.t_arrival is None:
            # first placement: anchor end-to-end delay + watchdog here so
            # retries keep counting from the ORIGINAL arrival
            req.t_arrival = self._clock()
        req.attempts += 1
        s = self.observe(req)
        self._key, k = jax.random.split(self._key)
        n = self._count % self.n_max
        eng, self.carry = self.scheduler.select_one_masked(
            self.carry, s, req.origin, n, k, avail)
        self._count += 1
        self.engines[eng].admit(req)
        return eng

    # ------------------------------------------------------------------
    # fault handling
    # ------------------------------------------------------------------
    def _now_rel(self) -> float:
        """Run-relative seconds (the injector's and trace's timebase)."""
        if self._t0 is None:
            self._t0 = self._clock()
        return self._clock() - self._t0

    def _apply_faults(self, now_rel: float) -> List[Request]:
        """Fire due injector events; returns terminal casualties."""
        if self.injector is None:
            return []
        terminal: List[Request] = []
        for ev in self.injector.due(now_rel):
            e = self.engines[ev.engine]
            self.fault_stats["injected"] += 1
            if ev.kind == "crash":
                if e.available:
                    terminal += self._crash(ev.engine, "injected crash")
            elif ev.kind == "recover":
                e.recover()
            elif ev.kind == "stall":
                if e.available:
                    e.degrade(stall_s=ev.duration_s,
                              reason="injected stall")
            elif ev.kind == "slowdown":
                if e.available:
                    e.degrade(slow_every=ev.factor,
                              reason="injected slowdown")
        return terminal

    def _crash(self, idx: int, reason: str) -> List[Request]:
        """Fail one engine, reclaim its KV, re-offload its requests."""
        now = self._clock()
        orphans = self.engines[idx].fail(reason)
        self.fault_stats["orphaned"] += len(orphans)
        terminal: List[Request] = []
        for r in orphans:
            r.t_orphaned = now
            terminal += self._requeue(r, now)
        return terminal

    def _requeue(self, r: Request, now: float) -> List[Request]:
        """Route one recovered request: retry with backoff, or give up."""
        r.reset_for_retry()
        if r.attempts >= self.retry.max_attempts:
            r.give_up("failed", f"retries exhausted "
                                f"({self.retry.max_attempts} attempts)")
            self.fault_stats["failed"] += 1
            return [r]
        if self.retry.hopeless(r, now):
            r.give_up("abandoned", "watchdog: deadline hopeless at retry")
            self.fault_stats["abandoned"] += 1
            return [r]
        ready = now + self.retry.backoff_s(r.attempts)
        heapq.heappush(self._retry_q, (ready, self._retry_seq, r))
        self._retry_seq += 1
        self.fault_stats["retries"] += 1
        return []

    def _park(self, r: Request, ready: float) -> None:
        """Hold an arrival that cannot be placed right now (total outage)
        until an engine comes back; does not consume a retry attempt."""
        heapq.heappush(self._retry_q, (ready, self._retry_seq, r))
        self._retry_seq += 1

    def _flush_retries(self, now: float) -> List[Request]:
        """Re-offload due retries; abandon the ones the watchdog flags.

        Hopeless entries are abandoned even during a total outage, so a
        never-recovering cluster still drains to a terminal state."""
        terminal: List[Request] = []
        while self._retry_q and self._retry_q[0][0] <= now:
            r = self._retry_q[0][-1]
            if self.retry.hopeless(r, now):
                heapq.heappop(self._retry_q)
                r.give_up("abandoned", "watchdog: deadline hopeless")
                self.fault_stats["abandoned"] += 1
                terminal.append(r)
                continue
            if not any(e.available for e in self.engines):
                break                   # total outage: wait for recovery
            heapq.heappop(self._retry_q)
            if r.t_orphaned is not None:
                self.fault_stats["orphan_recovery_s"].append(
                    now - r.t_orphaned)
                r.t_orphaned = None
            self.submit(r)
        return terminal

    def _shed_hopeless(self, now: float) -> List[Request]:
        """Watchdog sweep over every engine's queued (not yet running)
        requests — overload degrades by shedding, not by collapsing."""
        if not self._watchdog:
            return []
        terminal: List[Request] = []
        for e in self.engines:
            for r in e.shed(lambda r: self.retry.hopeless(r, now)):
                r.give_up("abandoned", "watchdog: deadline hopeless in "
                                       "queue")
                self.fault_stats["abandoned"] += 1
                terminal.append(r)
        return terminal

    # ------------------------------------------------------------------
    def step(self) -> List[Request]:
        """One cluster iteration; returns requests that reached a
        TERMINAL state this step (completed, failed, or abandoned).

        Overlapped stepping (default): ALL engines' rounds are
        dispatched before ANY engine's results are collected, so E
        engines' prefill chunks + decode rounds execute concurrently on
        device instead of serializing E blocking host round-trips — with
        bit-identical tokens/statuses to ``overlap=False`` serial
        stepping (each engine's dispatch->collect pair is exactly its
        serial ``step()``; only the interleaving across engines changes).

        Each engine's work is isolated: an exception quarantines that
        engine (DOWN, KV reclaimed, requests re-offloaded) instead of
        unwinding the whole closed loop."""
        now_rel = self._now_rel()
        now = self._clock()
        done: List[Request] = []
        done += self._apply_faults(now_rel)
        done += self._flush_retries(now)
        done += self._shed_hopeless(now)
        if not self.overlap:
            for i, e in enumerate(self.engines):
                if not e.available:
                    continue
                try:
                    done += e.step()
                except Exception as exc:  # noqa: BLE001 — quarantine all
                    self.fault_stats["quarantined"] += 1
                    done += self._crash(i, f"quarantined: {exc!r}")
            return done
        for i, e in enumerate(self.engines):
            if not e.available:
                continue
            try:
                e.dispatch()
            except Exception as exc:   # noqa: BLE001 — quarantine anything
                self.fault_stats["quarantined"] += 1
                done += self._crash(i, f"quarantined: {exc!r}")
        for i, e in enumerate(self.engines):
            if not e.available:         # crashed during dispatch: pending
                continue                # already dropped by fail()
            try:
                done += e.collect()
            except Exception as exc:   # noqa: BLE001 — quarantine anything
                self.fault_stats["quarantined"] += 1
                done += self._crash(i, f"quarantined: {exc!r}")
        return done

    @property
    def busy(self) -> bool:
        return (any(e.has_work for e in self.engines)
                or bool(self._retry_q))

    # ------------------------------------------------------------------
    def run(self, trace: Sequence[Request], max_steps: int = 1_000_000
            ) -> List[Request]:
        """Replay an arrival trace in real time; returns terminal requests.

        Requests become visible to the scheduler when the wall clock
        reaches their ``arrival_s``; ``service_s`` then measures the full
        arrival-to-finish delay (Eqn 2's serving-side terms).  Fault
        injector events share the same run-relative timebase.  Arrivals
        during a total outage are parked and placed on recovery.
        """
        todo = sorted(trace, key=lambda r: r.arrival_s)
        done: List[Request] = []
        i = 0
        # warm the scheduler's compiled select path outside the timed loop
        # (carry deliberately discarded: no counter/latent side effects)
        self.scheduler.select_one(
            self.carry, jnp.zeros((self.obs_dim,), jnp.float32),
            0, 0, jax.random.key(0))
        self._t0 = t0 = self._clock()
        for _ in range(max_steps):
            if i >= len(todo) and not self.busy:
                if self.injector is None or self.injector.exhausted:
                    break
                # quiescent but faults still scheduled: fast-forward
                self._apply_faults(self._now_rel())
                time.sleep(0.001)
                continue
            now = self._clock() - t0
            while i < len(todo) and todo[i].arrival_s <= now:
                todo[i].t_arrival = t0 + todo[i].arrival_s
                if any(e.available for e in self.engines):
                    self.submit(todo[i])
                else:                  # total outage: park until recovery
                    self._park(todo[i], self._clock())
                i += 1
            if self.busy:
                done += self.step()
                if not any(e.available and e.has_work
                           for e in self.engines):
                    time.sleep(0.001)  # only waiting on recovery/backoff
            elif i < len(todo):
                time.sleep(min(0.002,
                               max(todo[i].arrival_s - now, 0.0)))
        return done
