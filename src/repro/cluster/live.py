"""Closed-loop edge cluster: a Scheduler placing requests on live engines.

``EdgeCluster`` is the serving twin of the ``repro.core.env`` simulator:
the same Scheduler object (same carry, same trained weights) that drives
the jitted episode scan here sees MEASURED per-engine backlogs and places
real requests onto continuous-batching ``ServeEngine`` workers.

The observation handed to the scheduler mirrors Eqn (6):
``[d_n, workload_n, q_1..q_E]`` with d_n = prompt tokens, workload_n =
requested generation length (the z_n quality demand), and q_e = engine
backlog in pending tokens — each divided by a fixed scale so live
features land in the same O(1) range the policies trained on.

QoS-extended observation (``repro.workload``): when the scheduler was
built for the wider ``[.., slack, c_1..c_E]`` row, the cluster appends
the request's remaining deadline budget and a per-engine model-affinity
feature — the request's expected decode seconds on each engine, from the
engine's measured per-token rate (its live f_b'), inflated by
``pref_penalty`` on engines whose arch differs from the request's
``model_pref``.  The observation width is validated at CONSTRUCTION time
against ``scheduler.state_dim``, so a policy trained on the wrong
``EnvParams`` fails with a clear message instead of inside jit.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster.request import Request
from repro.cluster.schedulers import Scheduler


@dataclasses.dataclass(frozen=True)
class LiveObsConfig:
    """Feature scales mapping live measurements into the sim's O(1) range."""

    d_scale: float = 32.0      # prompt tokens
    w_scale: float = 16.0      # decode-token demand
    q_scale: float = 64.0      # backlog tokens
    # QoS-extended features
    slack_scale: float = 4.0   # seconds of remaining deadline budget
    slack_cap: float = 16.0    # best-effort requests report this slack
    c_scale: float = 1.0       # expected decode seconds on an engine
    pref_penalty: float = 4.0  # affinity inflation off the preferred arch


class EdgeCluster:
    """N engines + one scheduler, driven as a closed loop."""

    def __init__(self, engines: Sequence, scheduler: Scheduler,
                 obs: Optional[LiveObsConfig] = None, seed: int = 0,
                 clock: Callable[[], float] = time.monotonic,
                 qos_obs: Optional[bool] = None):
        if scheduler.num_engines != len(engines):
            raise ValueError(
                f"scheduler targets {scheduler.num_engines} engines, "
                f"cluster has {len(engines)}")
        self.engines = list(engines)
        for i, e in enumerate(self.engines):
            e.engine_id = i
        self.scheduler = scheduler
        self.obs = obs or LiveObsConfig()
        E = len(self.engines)
        base_dim, qos_dim = 2 + E, 3 + 2 * E
        sched_dim = getattr(scheduler, "state_dim", None)
        if qos_obs is None:
            qos_obs = sched_dim == qos_dim
        self.qos_obs = bool(qos_obs)
        self.obs_dim = qos_dim if self.qos_obs else base_dim
        if sched_dim is not None and sched_dim != self.obs_dim:
            raise ValueError(
                f"scheduler {scheduler.name!r} expects state_dim="
                f"{sched_dim}, but this {E}-engine cluster produces "
                f"{self.obs_dim}-feature observations "
                f"({'QoS-extended 3+2E' if self.qos_obs else 'base 2+E'}; "
                f"base={base_dim}, extended={qos_dim}).  Train the policy "
                f"on an EnvParams with num_bs={E} and "
                f"{'qos_mix set' if not self.qos_obs else 'no qos_mix'}, "
                f"or pass qos_obs= explicitly.")
        self.carry = scheduler.init_carry()
        self._key = jax.random.key(seed)
        self._count = 0
        self._clock = clock
        self.n_max = int(getattr(scheduler, "n_max", 1))

    # ------------------------------------------------------------------
    def observe(self, req: Request) -> jnp.ndarray:
        """Eqn-6 style observation row for one arriving request."""
        q = np.asarray([e.pending_tokens for e in self.engines], np.float32)
        prompt_len = req.prompt.shape[-1]
        cols = [np.asarray([prompt_len / self.obs.d_scale,
                            req.max_new_tokens / self.obs.w_scale],
                           np.float32),
                q / self.obs.q_scale]
        if self.qos_obs:
            budget = req.deadline_budget_s
            if budget is None:
                slack = self.obs.slack_cap
            else:
                elapsed = (0.0 if req.t_arrival is None
                           else self._clock() - req.t_arrival)
                slack = min(budget - elapsed, self.obs.slack_cap)
            aff = np.asarray([req.max_new_tokens * e.est_token_seconds
                              for e in self.engines], np.float32)
            if req.model_pref is not None:
                mismatch = np.asarray(
                    [getattr(e, "arch_id", None) != req.model_pref
                     for e in self.engines])
                aff = np.where(mismatch, aff * self.obs.pref_penalty, aff)
            cols.append(np.asarray([slack / self.obs.slack_scale],
                                   np.float32))
            cols.append(aff / self.obs.c_scale)
        return jnp.asarray(np.concatenate(cols))

    def submit(self, req: Request) -> int:
        """Scheduler picks an engine; the request joins its queue."""
        s = self.observe(req)
        self._key, k = jax.random.split(self._key)
        n = self._count % self.n_max
        eng, self.carry = self.scheduler.select_one(
            self.carry, s, req.origin, n, k)
        self._count += 1
        self.engines[eng].admit(req)
        return eng

    def step(self) -> List[Request]:
        done = []
        for e in self.engines:
            done += e.step()
        return done

    @property
    def busy(self) -> bool:
        return any(e.has_work for e in self.engines)

    # ------------------------------------------------------------------
    def run(self, trace: Sequence[Request], max_steps: int = 1_000_000
            ) -> List[Request]:
        """Replay an arrival trace in real time; returns finished requests.

        Requests become visible to the scheduler when the wall clock
        reaches their ``arrival_s``; ``service_s`` then measures the full
        arrival-to-finish delay (Eqn 2's serving-side terms).
        """
        todo = sorted(trace, key=lambda r: r.arrival_s)
        done: List[Request] = []
        i = 0
        # warm the scheduler's compiled select path outside the timed loop
        # (carry deliberately discarded: no counter/latent side effects)
        self.scheduler.select_one(
            self.carry, jnp.zeros((self.obs_dim,), jnp.float32),
            0, 0, jax.random.key(0))
        t0 = self._clock()
        for _ in range(max_steps):
            if i >= len(todo) and not self.busy:
                break
            now = self._clock() - t0
            while i < len(todo) and todo[i].arrival_s <= now:
                todo[i].t_arrival = t0 + todo[i].arrival_s
                self.submit(todo[i])
                i += 1
            if self.busy:
                done += self.step()
            elif i < len(todo):
                time.sleep(min(0.002,
                               max(todo[i].arrival_s - now, 0.0)))
        return done
