"""Edge-cluster scheduling layer: one Scheduler interface, two backends.

  * ``request``    — Request lifecycle (arrival, demand, per-phase
                     timestamps) + Poisson trace generation.
  * ``schedulers`` — the Scheduler protocol; trained-policy wrapper
                     (LAD-TS / D2SAC-TS / SAC-TS / DQN-TS) and non-learned
                     baselines (round-robin, JSQ, random, local-only).
  * ``simulate``   — run a Scheduler inside the jitted ``core.env`` scan.
  * ``live``       — run the SAME Scheduler against a cluster of
                     continuous-batching ``ServeEngine`` workers.
"""
from repro.cluster.live import EdgeCluster, LiveObsConfig
from repro.cluster.request import Request, poisson_trace, summarize
from repro.cluster.schedulers import (BASELINES, DeadlineAwareScheduler,
                                      FailureAwareScheduler,
                                      JoinShortestQueueScheduler,
                                      LocalOnlyScheduler, PolicyScheduler,
                                      PrefixAffinityScheduler,
                                      RandomScheduler, RoundRobinScheduler,
                                      Scheduler, make_scheduler)
from repro.cluster.simulate import build_sim_episode, evaluate_scheduler

__all__ = [
    "BASELINES", "DeadlineAwareScheduler", "EdgeCluster",
    "FailureAwareScheduler", "JoinShortestQueueScheduler", "LiveObsConfig",
    "LocalOnlyScheduler", "PolicyScheduler", "PrefixAffinityScheduler",
    "RandomScheduler", "Request",
    "RoundRobinScheduler", "Scheduler", "build_sim_episode",
    "evaluate_scheduler", "make_scheduler", "poisson_trace", "summarize",
]
