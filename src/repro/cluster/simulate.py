"""Drive any Scheduler against the jitted ``repro.core.env`` episode scan.

This is the evaluation half of the trainer's episode loop: no replay, no
updates — just the scheduler's ``select`` inside the (T x N x B) scan, with
queues coupling decisions via Eqn (4).  The same scheduler object (same
carry pytree) can then be handed to ``repro.cluster.live.EdgeCluster`` and
placed against real engines.

With a QoS-enabled ``EnvParams`` (``qos_mix`` set) the scan feeds the
scheduler the extended observation (deadline slack + per-ES affinity) and
``evaluate_scheduler`` reports the same QoS aggregates the live
``summarize()`` produces: per-class p50/p95/p99 delay, deadline-miss
rate, and priority-weighted goodput.

With a fault-enabled ``EnvParams`` (``fault`` set) every ES runs its
Bernoulli up/down chain inside the scan: the observation grows per-ES
availability columns, actions landing on a DOWN server are remapped to
the least-loaded available one with ``penalty_s`` added to that task's
delay, DOWN servers stop draining, and ``evaluate_scheduler`` reports
the ``wrong_choice_rate`` alongside the delay aggregates.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster.schedulers import Scheduler
from repro.core import env as envlib


def build_sim_episode(scheduler: Scheduler, p: envlib.EnvParams) -> Callable:
    """episode(carry, ep_data, key) -> (carry, delays (T,N,B), mask[, wrong]).

    With ``p.has_faults`` the returned callable yields a fourth array —
    per-task wrong-choice flags (the scheduler picked a DOWN server and
    was remapped) of the same (T, N, B) shape.  Without faults the
    availability vector rides the carry as inert ones and every computed
    quantity (observations, RNG stream, delays) is bit-identical to the
    legacy scan.
    """
    scale = envlib.state_scale(p)

    def episode(carry, ep: envlib.EpisodeData, key):
        qs0 = envlib.init_queues(p)

        def task_step(inner, tn):
            sc, qs, av, key = inner
            t, n = tn
            key, k_sel = jax.random.split(key)
            d = ep.d[t, n]
            workload = ep.rho[t, n] * ep.z[t, n]
            s = envlib.observe(p, qs, d, workload,
                               slack=ep.deadline[t, n],
                               f=ep.f, avail=av) / scale[None, :]
            actions, sc = scheduler.select(sc, s, n, k_sel)
            actions = actions % p.num_bs
            if p.has_faults:
                actions, wrong = envlib.mask_actions(av, qs.q_prev + qs.q_bef,
                                                     actions)
                penalty = p.fault.penalty_s * wrong
            else:
                wrong = jnp.zeros((p.num_bs,), bool)
                penalty = 0.0
            delays = envlib.task_delays(p, ep, qs, t, n, actions) + penalty
            qs = envlib.apply_actions(p, ep, qs, t, n, actions)
            return (sc, qs, av, key), (delays, ep.mask[t, n], wrong)

        def slot_step(inner, t):
            ns = jnp.arange(p.max_tasks)
            inner, per_task = jax.lax.scan(
                task_step, inner, (jnp.full_like(ns, t), ns))
            sc, qs, av, key = inner
            if p.has_faults:
                qs = envlib.end_slot(p, ep, qs, avail=av)
                av = envlib.step_avail(p.fault, av, ep.avail_u[t])
            else:
                qs = envlib.end_slot(p, ep, qs)
            return (sc, qs, av, key), per_task

        av0 = envlib.init_avail(p.num_bs)
        (sc, _, _, _), (delays, mask, wrong) = jax.lax.scan(
            slot_step, (carry, qs0, av0, key), jnp.arange(p.num_slots))
        if p.has_faults:
            return sc, delays, mask, wrong
        return sc, delays, mask

    return episode


def _percentiles(delays: np.ndarray) -> dict:
    if delays.size == 0:
        return {"mean_s": 0.0, "p50_s": 0.0, "p95_s": 0.0, "p99_s": 0.0}
    return {"mean_s": float(delays.mean()),
            "p50_s": float(np.percentile(delays, 50)),
            "p95_s": float(np.percentile(delays, 95)),
            "p99_s": float(np.percentile(delays, 99))}


def evaluate_scheduler(scheduler: Scheduler, p: envlib.EnvParams,
                       episodes: int, key, f: Optional[jnp.ndarray] = None,
                       carry=None) -> dict:
    """Delay percentiles (+ QoS aggregates) over fresh episodes."""
    episode = jax.jit(build_sim_episode(scheduler, p))
    key, k_f = jax.random.split(key)
    if f is None:
        f = envlib.sample_capacities(k_f, p)
    if carry is None:
        carry = scheduler.init_carry()
    all_delays, all_cls, all_dl, all_prio, all_wrong = [], [], [], [], []
    for _ in range(episodes):
        key, k_ep, k_run = jax.random.split(key, 3)
        ep_data = envlib.sample_episode(k_ep, p, f=f)
        res = episode(carry, ep_data, k_run)
        carry, delays, mask = res[0], res[1], res[2]
        sel = np.asarray(mask) > 0
        all_delays.append(np.asarray(delays)[sel])
        all_cls.append(np.asarray(ep_data.cls)[sel])
        all_dl.append(np.asarray(ep_data.deadline)[sel])
        all_prio.append(np.asarray(ep_data.priority)[sel])
        if p.has_faults:
            all_wrong.append(np.asarray(res[3])[sel])
    delays = np.concatenate(all_delays) if all_delays else np.zeros((0,))
    out = {"count": int(delays.size), **_percentiles(delays)}
    # schema parity with the live summarize(): the slot-based sim has no
    # KV model, so cache efficiency is identically zero here — but the
    # keys exist so sim and live records compare column-for-column
    out["prefill_tokens_saved"] = 0
    out["prefix_hit_rate"] = 0.0
    if p.has_faults:
        wrong = (np.concatenate(all_wrong) if all_wrong
                 else np.zeros((0,), bool))
        # sim tasks always complete (wrong picks are remapped + penalised),
        # so the terminal-status schema matches the live summarize() shape
        out.update(completed=int(delays.size), failed=0, abandoned=0,
                   retries=0, completion_rate=1.0,
                   wrong_choice_rate=(float(wrong.mean())
                                      if wrong.size else 0.0))
    if p.has_qos and delays.size:
        cls = np.concatenate(all_cls)
        dl = np.concatenate(all_dl)
        prio = np.concatenate(all_prio)
        missed = delays > dl
        has_dl = np.isfinite(dl)
        out["deadline_miss_rate"] = (float(missed[has_dl].mean())
                                     if has_dl.any() else 0.0)
        out["weighted_goodput"] = float((prio * ~missed).sum()
                                        / max(prio.sum(), 1e-9))
        classes = {}
        for i, (c, _) in enumerate(p.qos_mix):
            m = cls == i
            if not m.any():
                continue
            c_dl = m & has_dl
            classes[c.name] = {
                "count": int(m.sum()),
                "priority": float(c.priority),
                **_percentiles(delays[m]),
                "deadline_miss_rate": (float(missed[c_dl].mean())
                                       if c_dl.any() else 0.0),
                "weighted_goodput": float((prio[m] * ~missed[m]).sum()
                                          / max(prio[m].sum(), 1e-9)),
            }
        out["classes"] = classes
    out["carry"] = carry
    return out
