"""GQA attention: full-causal, sliding-window, and single-token decode.

Three execution paths share one set of weights:

  * ``attend_train``    — full sequence, causal (optionally windowed).
    Uses a memory-bounded chunked online-softmax formulation (pure jnp,
    lax.scan over query chunks) so 32k-token prefill never materialises an
    S x S score matrix.  The Pallas flash kernel (repro.kernels) is the TPU
    hot path; this is its reference/lowering twin.
  * ``prefill``         — attend_train + emit a KV cache.
  * ``decode``          — one token against a cache (full-length or
    ring-buffer windowed).

Cache layout: {"k": (B, KV, S, hd), "v": (B, KV, S, hd), "pos": ()}.
``pos`` = number of tokens already written.  Windowed caches are ring
buffers of size W written at ``pos % W``.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.launch import sharding
from repro.models.layers import apply_rope, dense_init

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def init_attention(key, cfg, dtype) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, cfg.num_heads * hd, dtype),
        "wk": dense_init(ks[1], d, cfg.num_kv_heads * hd, dtype),
        "wv": dense_init(ks[2], d, cfg.num_kv_heads * hd, dtype),
        "wo": dense_init(ks[3], cfg.num_heads * hd, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.num_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
    return p


def _project_qkv(p, cfg, x, positions, use_rope):
    """x (B,S,d) -> q (B,S,H,hd), k/v (B,S,KV,hd), with RoPE applied."""
    B, S, _ = x.shape
    hd = cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, cfg.num_heads, hd)
    k = k.reshape(B, S, cfg.num_kv_heads, hd)
    v = v.reshape(B, S, cfg.num_kv_heads, hd)
    q = sharding.act(q, "batch", "seq", "heads", None)
    k = sharding.act(k, "batch", "seq", "kv_heads", None)
    v = sharding.act(v, "batch", "seq", "kv_heads", None)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


# ---------------------------------------------------------------------------
# chunked causal attention (online softmax, pure jnp)
# ---------------------------------------------------------------------------


def _pick_chunk(S: int, target: int = 1024) -> int:
    c = min(S, target)
    while S % c:
        c //= 2
    return max(c, 1)


def chunked_causal_attention(q, k, v, *, window: Optional[int] = None,
                             q_offset: int = 0,
                             q_chunk: int = 1024, kv_chunk: int = 1024):
    """q (B,Sq,H,hd); k,v (B,Skv,KV,hd) -> (B,Sq,H,hd).

    Causal within absolute positions: query i (at q_offset+i) attends keys
    j <= q_offset+i and, if windowed, j > q_offset+i - window.
    """
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    q_chunk = _pick_chunk(Sq, q_chunk)
    kv_chunk = _pick_chunk(Skv, kv_chunk)
    nq, nk = Sq // q_chunk, Skv // kv_chunk
    scale = 1.0 / math.sqrt(hd)

    # keep streams in model dtype; accumulate in f32 inside each block
    qc = q.reshape(B, nq, q_chunk, KV, G, hd)
    kc = k.reshape(B, nk, kv_chunk, KV, hd)
    vc = v.reshape(B, nk, kv_chunk, KV, hd)
    # scan over q chunks; inner scan over kv chunks with online softmax.
    q_starts = jnp.arange(nq) * q_chunk + q_offset
    kv_starts = jnp.arange(nk) * kv_chunk

    def q_step(_, inp):
        qi, qstart = inp                       # (B,Cq,KV,G,hd), ()
        qpos = qstart + jnp.arange(q_chunk)    # (Cq,)

        @functools.partial(
            jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable)
        def kv_step(carry, kv_inp):
            # Checkpointed: the (Cq x Ckv) score/prob blocks are recomputed
            # in the backward pass instead of being saved per scan iteration
            # — the jnp twin of the flash-attention recompute trick.
            m, l, acc = carry
            ki, vi, kstart = kv_inp
            kpos = kstart + jnp.arange(kv_chunk)
            s = jnp.einsum("bqkgd,bckd->bqkgc", qi, ki,
                           preferred_element_type=jnp.float32) * scale
            mask = kpos[None, :] <= qpos[:, None]          # (Cq,Ckv)
            if window is not None:
                mask &= kpos[None, :] > qpos[:, None] - window
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p_ = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p_.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bqkgc,bckd->bqkgd", p_.astype(vi.dtype), vi,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, q_chunk, KV, G), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, q_chunk, KV, G), jnp.float32)
        a0 = jnp.zeros((B, q_chunk, KV, G, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      (kc.swapaxes(0, 1), vc.swapaxes(0, 1),
                                       kv_starts))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out

    _, out = jax.lax.scan(q_step, None, (qc.swapaxes(0, 1), q_starts))
    out = out.swapaxes(0, 1).reshape(B, Sq, H, hd)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# block-level entry points
# ---------------------------------------------------------------------------


def attend_train(p, cfg, blk, x, positions) -> jnp.ndarray:
    """Full-sequence causal attention (train / prefill forward)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x, positions, blk.use_rope)
    out = chunked_causal_attention(q, k, v, window=blk.window,
                                   q_chunk=cfg.attn_chunk,
                                   kv_chunk=cfg.attn_chunk)
    out = sharding.act(out, "batch", "seq", "heads", None)
    out = out.reshape(B, S, cfg.num_heads * cfg.head_dim)
    return out @ p["wo"]


def _cache_dtype(cfg):
    if cfg.kv_cache_dtype == "int8":
        return jnp.int8
    return jnp.dtype(cfg.dtype)


def _quantize_kv(x):
    """(..., S, hd) -> (int8 values, f32 scales (..., S, 1)).

    Symmetric per-(head, position) scaling: one scale per cache slot."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def _dequantize_kv(q, scale, dtype):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def init_kv_cache(cfg, blk, batch: int, max_len: int, make=jnp.zeros,
                  dtype=None):
    """Empty cache.  ``make`` can be jax.ShapeDtypeStruct for dry-runs."""
    dtype = dtype or _cache_dtype(cfg)
    W = blk.window or max_len
    W = min(W, max_len)
    kv = cfg.num_kv_heads
    cache = {
        "k": make((batch, kv, W, cfg.head_dim), dtype),
        "v": make((batch, kv, W, cfg.head_dim), dtype),
        "pos": make((), jnp.int32),
    }
    if cfg.kv_cache_dtype == "int8":
        cache["k_scale"] = make((batch, kv, W, 1), jnp.float32)
        cache["v_scale"] = make((batch, kv, W, 1), jnp.float32)
    return cache


def prefill(p, cfg, blk, x, positions, max_len: Optional[int] = None
            ) -> Tuple[jnp.ndarray, dict]:
    """Forward over the prompt; returns (out, cache).

    ``max_len`` sizes the emitted cache (>= S) so decode steps have room;
    windowed blocks emit a ring buffer of size min(window, max_len) with
    position p stored at slot p % W (matching :func:`decode`).
    """
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x, positions, blk.use_rope)
    out = chunked_causal_attention(q, k, v, window=blk.window,
                                   q_chunk=cfg.attn_chunk,
                                   kv_chunk=cfg.attn_chunk)
    out = out.reshape(B, S, cfg.num_heads * cfg.head_dim) @ p["wo"]
    kT = k.swapaxes(1, 2)   # (B,KV,S,hd)
    vT = v.swapaxes(1, 2)
    max_len = max(max_len or S, S if blk.window is None else 0)
    W = min(blk.window, max_len) if blk.window is not None else max_len
    if W >= S:
        # position p < S <= W lands at ring slot p % W == p: right-pad.
        pad = W - S
        kr = jnp.pad(kT, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vr = jnp.pad(vT, ((0, 0), (0, 0), (0, pad), (0, 0)))
    else:
        # keep the last W positions, placed at their ring slots p % W
        last_pos = jnp.arange(S - W, S)
        slots = last_pos % W
        order = jnp.argsort(slots)
        kr = jnp.take(kT[:, :, S - W:], order, axis=2)
        vr = jnp.take(vT[:, :, S - W:], order, axis=2)
    cache = {"pos": jnp.asarray(S, jnp.int32)}
    if cfg.kv_cache_dtype == "int8":
        kq, ks = _quantize_kv(kr)
        vq, vs = _quantize_kv(vr)
        cache["k"] = sharding.act(kq, "batch", "kv_heads", "kv_seq", None)
        cache["v"] = sharding.act(vq, "batch", "kv_heads", "kv_seq", None)
        cache["k_scale"] = sharding.act(ks, "batch", "kv_heads", "kv_seq",
                                        None)
        cache["v_scale"] = sharding.act(vs, "batch", "kv_heads", "kv_seq",
                                        None)
    else:
        cache["k"] = sharding.act(kr, "batch", "kv_heads", "kv_seq", None)
        cache["v"] = sharding.act(vr, "batch", "kv_heads", "kv_seq", None)
    return out, cache


# ---------------------------------------------------------------------------
# paged KV cache (vLLM-style shared page pool)
# ---------------------------------------------------------------------------
#
# Layout: one pool per layer, {"k_pages": (P, KV, ps, hd), "v_pages": ...};
# page 0 is the allocator's reserved null page (never handed to a live
# sequence), so clamped/unmapped block-table entries and masked write lanes
# land there harmlessly.  The block table (pages_per_seq ids per sequence)
# is SHARED across layers: every layer writes the same logical positions,
# so one allocation describes all of them.


def init_paged_kv_cache(cfg, blk, num_pages: int, page_size: int,
                        make=jnp.zeros):
    """Empty per-layer page pool.  ``make`` may be jax.ShapeDtypeStruct."""
    if blk.window is not None or cfg.kv_cache_dtype == "int8":
        raise ValueError(
            "paged KV serving supports full-attention model-dtype caches "
            f"only (window={blk.window}, kv_cache_dtype="
            f"{cfg.kv_cache_dtype})")
    dtype = _cache_dtype(cfg)
    kv = cfg.num_kv_heads
    return {"k_pages": make((num_pages, kv, page_size, cfg.head_dim), dtype),
            "v_pages": make((num_pages, kv, page_size, cfg.head_dim), dtype)}


def _page_of(block_table, pos, page_size):
    """Physical page ids for logical positions; overshoot clamps onto the
    table's trailing null-padded entries (see ServeEngine row padding)."""
    idx = jnp.clip(pos // page_size, 0, block_table.shape[-1] - 1)
    return jnp.take_along_axis(block_table, idx, axis=-1)


def _scatter_pages(pages, vals, block_table, start):
    """Write ``vals`` (n, KV, hd) at positions start..start+n-1 of one
    sequence.  pages (P, KV, ps, hd); block_table (pages_per_seq,)."""
    ps = pages.shape[2]
    pos = start + jnp.arange(vals.shape[0])
    page = _page_of(block_table, pos, ps)
    return pages.at[page, :, pos % ps].set(vals.astype(pages.dtype))


def _gather_pages(pages, block_table, max_ctx: int):
    """Dense (max_ctx, KV, hd) view of one sequence's pages (garbage past
    the written length — callers mask by position)."""
    ps = pages.shape[2]
    pos = jnp.arange(max_ctx)
    page = _page_of(block_table, pos, ps)
    return pages[page, :, pos % ps]


def paged_prefill_chunk(p, cfg, blk, x, cache, block_table, start
                        ) -> Tuple[jnp.ndarray, dict]:
    """One prompt chunk through paged attention.  x (1, C, d) holds tokens
    at absolute positions start..start+C-1 (tail may be padding — pad
    positions are only ever read causally by pad queries, and decode
    overwrites their page slots before reading them).

    Writes the chunk's KV into the pool, then attends the chunk's queries
    against the full gathered context with absolute causal masking — so a
    long prompt admits as a sequence of these calls interleaved with
    decode rounds instead of one blocking batch-1 prefill.
    """
    B, C, _ = x.shape
    positions = start + jnp.arange(C)[None]                    # (1, C)
    q, k, v = _project_qkv(p, cfg, x, positions, blk.use_rope)
    k_pages = _scatter_pages(cache["k_pages"], k[0], block_table, start)
    v_pages = _scatter_pages(cache["v_pages"], v[0], block_table, start)
    max_ctx = block_table.shape[-1] * k_pages.shape[2]
    kd = _gather_pages(k_pages, block_table, max_ctx)[None]    # (1,ctx,KV,hd)
    vd = _gather_pages(v_pages, block_table, max_ctx)[None]
    out = chunked_causal_attention(q, kd, vd, q_offset=start,
                                   q_chunk=cfg.attn_chunk,
                                   kv_chunk=cfg.attn_chunk)
    out = out.reshape(B, C, cfg.num_heads * cfg.head_dim) @ p["wo"]
    return out, {"k_pages": k_pages, "v_pages": v_pages}


def paged_decode(p, cfg, blk, x, cache, block_tables, lengths
                 ) -> Tuple[jnp.ndarray, dict]:
    """One-token decode over the shared page pool.

    x (B, 1, d); lengths (B,) tokens already written per lane.  Writes the
    new token's KV at position lengths[b] of each lane's block table, then
    attends lengths[b]+1 tokens via the paged flash-decode kernel (TPU)
    or its XLA gather twin (CPU).  Inactive lanes pass a null block table
    (all page 0) and length 0 — their writes and reads hit the reserved
    null page and their outputs are discarded by the engine.
    """
    from repro.kernels import ops as kernel_ops

    B = x.shape[0]
    hd = cfg.head_dim
    q, k, v = _project_qkv(p, cfg, x, lengths[:, None], blk.use_rope)
    ps = cache["k_pages"].shape[2]
    page = _page_of(block_tables, lengths[:, None], ps)[:, 0]  # (B,)
    slot = lengths % ps
    dtype = cache["k_pages"].dtype
    k_pages = cache["k_pages"].at[page, :, slot].set(
        k[:, 0].astype(dtype))
    v_pages = cache["v_pages"].at[page, :, slot].set(
        v[:, 0].astype(dtype))
    out = kernel_ops.paged_flash_decode(q[:, 0], k_pages, v_pages,
                                        block_tables, lengths + 1)
    out = out.reshape(B, 1, cfg.num_heads * hd).astype(x.dtype)
    out = out @ p["wo"]
    return out, {"k_pages": k_pages, "v_pages": v_pages}


def decode(p, cfg, blk, x, cache) -> Tuple[jnp.ndarray, dict]:
    """One-token decode.  x (B,1,d); cache holds ``pos`` tokens already."""
    B = x.shape[0]
    hd = cfg.head_dim
    pos = cache["pos"]                                   # scalar int32
    q, k, v = _project_qkv(p, cfg, x, jnp.full((B, 1), pos), blk.use_rope)
    W = cache["k"].shape[2]
    slot = pos % W
    quant = cfg.kv_cache_dtype == "int8"

    k_new = k.swapaxes(1, 2)
    v_new = v.swapaxes(1, 2)
    new_cache = {"pos": pos + 1}
    if quant:
        kq, ks = _quantize_kv(k_new)
        vq, vs = _quantize_kv(v_new)
        upd = jax.lax.dynamic_update_slice_in_dim
        k_cache = upd(cache["k"], kq, slot, axis=2)
        v_cache = upd(cache["v"], vq, slot, axis=2)
        k_scale = upd(cache["k_scale"], ks, slot, axis=2)
        v_scale = upd(cache["v_scale"], vs, slot, axis=2)
        new_cache.update(k_scale=k_scale, v_scale=v_scale)
        k_read = _dequantize_kv(k_cache, k_scale, jnp.float32)
        v_read = _dequantize_kv(v_cache, v_scale, jnp.float32)
    else:
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k_new, slot, axis=2)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v_new, slot, axis=2)
        k_read = k_cache.astype(jnp.float32)
        v_read = v_cache.astype(jnp.float32)
    k_cache = sharding.act(k_cache, "batch", "kv_heads", "kv_seq", None)
    v_cache = sharding.act(v_cache, "batch", "kv_heads", "kv_seq", None)
    new_cache.update(k=k_cache, v=v_cache)

    KV, G = cfg.num_kv_heads, cfg.num_heads // cfg.num_kv_heads
    qh = q.reshape(B, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgd,bksd->bkgs", qh, k_read)
    s *= 1.0 / math.sqrt(hd)
    # ring-buffer validity: slot j is populated iff j <= pos or the buffer
    # has wrapped (pos >= W); window semantics are implied by ring size.
    valid = (jnp.arange(W) <= pos) | (pos >= W)
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bksd->bkgd", w, v_read)
    out = out.reshape(B, 1, cfg.num_heads * hd).astype(x.dtype)
    out = out @ p["wo"]
    return out, new_cache
