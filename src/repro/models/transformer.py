"""Unified decoder: one model covering all six assigned arch families.

A model is a stack of blocks described by ``cfg.layer_pattern()``.  Layers
are evaluated either
  * flat (scan_layers=False; CPU smoke tests), or
  * grouped lax.scan over repeating pattern groups (scan_layers=True) —
    keeps HLO size O(1) in depth, which is what makes 512-partition
    dry-run compiles tractable.  ``num_layers % len(pattern)`` remainder
    layers are applied unscanned after the scan.

Three entry points, matched to the assigned input-shape kinds:
  * ``forward(..., mode="train")``    -> hidden states (loss lives in
    repro.train.losses, chunked so logits are never fully materialised)
  * ``forward(..., mode="prefill")``  -> last-token logits + filled caches
  * ``forward(..., mode="decode")``   -> one-token logits + updated caches

Modality carve-outs (per assignment): the audio conv-codec and the VLM
vision tower are stubs — inputs arrive as token streams / patch embeddings;
the codebook embedding sum, per-codebook heads, and multimodal projector
are implemented for real.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.launch import sharding
from repro.models import attention, moe, rglru, xlstm
from repro.models.layers import (dense_init, init_mlp, init_rmsnorm,
                                 apply_mlp, rmsnorm, sinusoidal_positions)

# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------


def _init_block(key, cfg, blk, dtype) -> dict:
    ks = jax.random.split(key, 3)
    p: Dict[str, Any] = {"norm1": init_rmsnorm(cfg.d_model, dtype)}
    if blk.mixer == "attn":
        p["mixer"] = attention.init_attention(ks[0], cfg, dtype)
    elif blk.mixer == "mlstm":
        p["mixer"] = xlstm.init_mlstm(ks[0], cfg, dtype)
    elif blk.mixer == "slstm":
        p["mixer"] = xlstm.init_slstm(ks[0], cfg, dtype)
    elif blk.mixer == "rglru":
        p["mixer"] = rglru.init_rglru(ks[0], cfg, dtype)
    else:
        raise ValueError(blk.mixer)
    if blk.ffn != "none":
        p["norm2"] = init_rmsnorm(cfg.d_model, dtype)
        if blk.ffn == "dense":
            p["ffn"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.gated_ffn,
                                dtype)
        elif blk.ffn == "moe":
            p["ffn"] = moe.init_moe(ks[1], cfg, dtype)
        else:
            raise ValueError(blk.ffn)
    return p


def _layer_layout(cfg) -> Tuple[int, int]:
    """(n_groups, remainder) for grouped layer scan."""
    P = len(cfg.pattern)
    return cfg.num_layers // P, cfg.num_layers % P


def init_params(key, cfg) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    pattern = cfg.layer_pattern()
    P = len(cfg.pattern)
    keys = jax.random.split(key, cfg.num_layers + 4)

    params: Dict[str, Any] = {}
    # embeddings ------------------------------------------------------------
    if cfg.num_codebooks:
        emb = jnp.stack([dense_init(k, cfg.vocab_size, cfg.d_model, dtype)
                         for k in jax.random.split(keys[-1],
                                                   cfg.num_codebooks)])
    else:
        emb = dense_init(keys[-1], cfg.vocab_size, cfg.d_model, dtype)
    params["embed"] = {"embed": emb}
    if cfg.vision_patches:
        k1, k2 = jax.random.split(keys[-2])
        params["projector"] = {
            "w_proj": dense_init(k1, cfg.vision_dim, cfg.d_model, dtype),
            "w_up": dense_init(k2, cfg.d_model, cfg.d_model, dtype),
        }
    params["final_norm"] = init_rmsnorm(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        if cfg.num_codebooks:
            head = jnp.stack([dense_init(k, cfg.d_model, cfg.vocab_size,
                                         dtype)
                              for k in jax.random.split(
                                  keys[-3], cfg.num_codebooks)])
        else:
            head = dense_init(keys[-3], cfg.d_model, cfg.vocab_size, dtype)
        params["head"] = {"head": head}

    # layers ----------------------------------------------------------------
    layer_params = [
        _init_block(keys[i], cfg, pattern[i], dtype)
        for i in range(cfg.num_layers)
    ]
    if cfg.scan_layers:
        n_groups, rem = _layer_layout(cfg)
        scan, remp = [], []
        if n_groups > 0:
            for j in range(P):
                stack = [layer_params[g * P + j] for g in range(n_groups)]
                scan.append(jax.tree_util.tree_map(
                    lambda *xs: jnp.stack(xs), *stack))
        for j in range(rem):
            remp.append(layer_params[n_groups * P + j])
        params["layers"] = {"scan": scan, "rem": remp}
    else:
        params["layers"] = {"flat": layer_params}
    return params


def abstract_params(cfg) -> dict:
    """Parameter ShapeDtypeStructs without allocating (dry-run path)."""
    return jax.eval_shape(functools.partial(init_params, cfg=cfg),
                          jax.random.key(0))


# ---------------------------------------------------------------------------
# per-layer state ("KV cache" generalised to recurrent families)
# ---------------------------------------------------------------------------


def _init_block_state(cfg, blk, batch, max_len, make):
    if blk.mixer == "attn":
        return attention.init_kv_cache(cfg, blk, batch, max_len, make=make)
    if blk.mixer == "mlstm":
        return xlstm.init_mlstm_state(cfg, batch, make=make)
    if blk.mixer == "slstm":
        return xlstm.init_slstm_state(cfg, batch, make=make)
    if blk.mixer == "rglru":
        return rglru.init_rglru_state(cfg, batch, make=make)
    raise ValueError(blk.mixer)


def init_layer_states(cfg, batch: int, max_len: int, make=jnp.zeros,
                      filled_pos: Optional[int] = None) -> dict:
    """State pytree matching the params layer layout.

    ``make(shape, dtype)`` may be jnp.zeros or jax.ShapeDtypeStruct.
    ``filled_pos`` stamps a concrete token count (decode dry-runs pretend a
    ``seq_len``-deep cache is already populated).
    """
    pattern = cfg.layer_pattern()
    P = len(cfg.pattern)

    def one(blk):
        st = _init_block_state(cfg, blk, batch, max_len, make)
        if filled_pos is not None and make is jnp.zeros:
            st["pos"] = jnp.asarray(filled_pos, jnp.int32)
        return st

    if cfg.scan_layers:
        n_groups, rem = _layer_layout(cfg)
        if n_groups == 0:
            return {"scan": [],
                    "rem": [one(pattern[j]) for j in range(rem)]}

        def stacked(blk):
            base = one(blk)
            return jax.tree_util.tree_map(
                lambda leaf: (jax.ShapeDtypeStruct((n_groups,) + leaf.shape,
                                                   leaf.dtype)
                              if isinstance(leaf, jax.ShapeDtypeStruct)
                              else jnp.broadcast_to(
                                  leaf, (n_groups,) + leaf.shape)),
                base)

        return {"scan": [stacked(pattern[j]) for j in range(P)],
                "rem": [one(pattern[n_groups * P + j]) for j in range(rem)]}
    return {"flat": [one(b) for b in pattern]}


def init_paged_states(cfg, num_pages: int, page_size: int,
                      make=jnp.zeros) -> dict:
    """Per-layer shared page pools, mirroring the params layer layout.

    One (num_pages, KV, page_size, hd) k/v pool per layer; the block table
    mapping sequences to pages lives in the serving engine (it is shared
    across layers, so it is not part of this state pytree).  Only
    all-attention stacks can be paged — recurrent mixers have no KV to
    page (the engine falls back to dense slot caches for those).
    """
    pattern = cfg.layer_pattern()
    P = len(cfg.pattern)

    def one(blk):
        if blk.mixer != "attn":
            raise ValueError(
                f"paged serving requires attention mixers, got {blk.mixer}")
        return attention.init_paged_kv_cache(cfg, blk, num_pages, page_size,
                                             make=make)

    if cfg.scan_layers:
        n_groups, rem = _layer_layout(cfg)
        if n_groups == 0:
            return {"scan": [],
                    "rem": [one(pattern[j]) for j in range(rem)]}

        def stacked(blk):
            base = one(blk)
            return jax.tree_util.tree_map(
                lambda leaf: (jax.ShapeDtypeStruct((n_groups,) + leaf.shape,
                                                   leaf.dtype)
                              if isinstance(leaf, jax.ShapeDtypeStruct)
                              else jnp.broadcast_to(
                                  leaf, (n_groups,) + leaf.shape)),
                base)

        return {"scan": [stacked(pattern[j]) for j in range(P)],
                "rem": [one(pattern[n_groups * P + j]) for j in range(rem)]}
    return {"flat": [one(b) for b in pattern]}


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------


def _apply_block(cfg, blk, p, x, positions, state, mode, max_len=None,
                 paged=None):
    """Returns (x_out, new_state, aux)."""
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    new_state = state
    if mode in ("paged_prefill", "paged_decode") and blk.mixer != "attn":
        raise ValueError(
            f"paged serving requires attention mixers, got {blk.mixer}")
    if blk.mixer == "attn":
        if mode == "train":
            mix = attention.attend_train(p["mixer"], cfg, blk, h, positions)
        elif mode == "prefill":
            mix, new_state = attention.prefill(p["mixer"], cfg, blk, h,
                                               positions, max_len=max_len)
        elif mode == "paged_prefill":
            mix, new_state = attention.paged_prefill_chunk(
                p["mixer"], cfg, blk, h, state, paged["block_table"],
                paged["start"])
        elif mode == "paged_decode":
            mix, new_state = attention.paged_decode(
                p["mixer"], cfg, blk, h, state, paged["block_tables"],
                paged["lengths"])
        else:
            mix, new_state = attention.decode(p["mixer"], cfg, blk, h, state)
    elif blk.mixer == "mlstm":
        if mode == "decode":
            mix, new_state = xlstm.mlstm_step(p["mixer"], cfg, h, state)
        else:
            mix, new_state = xlstm.mlstm_scan(p["mixer"], cfg, h)
    elif blk.mixer == "slstm":
        if mode == "decode":
            mix, new_state = xlstm.slstm_step(p["mixer"], cfg, h, state)
        else:
            mix, new_state = xlstm.slstm_scan(p["mixer"], cfg, h)
    elif blk.mixer == "rglru":
        if mode == "decode":
            mix, new_state = rglru.rglru_step(p["mixer"], cfg, h, state)
        else:
            mix, new_state = rglru.rglru_scan(
                p["mixer"], cfg, h,
                use_assoc_scan=getattr(cfg, "use_assoc_scan", False))
    else:
        raise ValueError(blk.mixer)
    x = x + mix
    aux = jnp.zeros((), jnp.float32)
    if blk.ffn != "none":
        h2 = rmsnorm(p["norm2"], x, cfg.norm_eps)
        if blk.ffn == "dense":
            ctx = sharding.current()
            dp = ctx.sharding("batch", None, "ff") if ctx else None
            y = apply_mlp(p["ffn"], h2, dp_spec=dp)
        else:
            y, aux = moe.apply_moe(p["ffn"], cfg, h2)
        x = x + y
    x = sharding.act(x, "batch", "seq", None)
    return x, new_state, aux


# ---------------------------------------------------------------------------
# embeddings & heads
# ---------------------------------------------------------------------------


def embed_inputs(params, cfg, inputs: dict, pos_offset) -> jnp.ndarray:
    emb = params["embed"]["embed"]
    if cfg.num_codebooks:
        toks = inputs["tokens"]                 # (B, K, S)
        x = sum(emb[k][toks[:, k]] for k in range(cfg.num_codebooks))
    else:
        x = emb[inputs["tokens"]]               # (B, S, d)
    if cfg.vision_patches and "patches" in inputs:
        pr = params["projector"]
        pe = jax.nn.gelu(inputs["patches"] @ pr["w_proj"]) @ pr["w_up"]
        x = jnp.concatenate([pe.astype(x.dtype), x], axis=1)
    if not cfg.use_rope:
        S = x.shape[1]
        pos = pos_offset + jnp.arange(S)   # (S,) or (B, S) if offset (B, 1)
        pe = sinusoidal_positions(pos, cfg.d_model)
        if pe.ndim == 2:
            pe = pe[None]
        x = x + pe.astype(x.dtype)
    return sharding.act(x, "batch", "seq", None)


def apply_head(params, cfg, x) -> jnp.ndarray:
    """x (B, S, d) -> logits (B, S, V) or (B, S, K, V)."""
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["embed"].T
    elif cfg.num_codebooks:
        logits = jnp.einsum("bsd,kdv->bskv", x, params["head"]["head"])
    else:
        logits = x @ params["head"]["head"]
    return logits


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _scan_layers(cfg, layers, x, positions, states, mode, max_len=None,
                 paged=None):
    """Grouped scan over layers.  Returns (x, new_states, aux_sum)."""
    pattern = cfg.layer_pattern()
    P = len(cfg.pattern)
    n_groups, rem = _layer_layout(cfg)
    aux_total = jnp.zeros((), jnp.float32)

    if n_groups > 0:
        def group_body(carry, xs):
            xc, aux = carry
            gp, gs = xs            # per-position lists stacked over groups
            new_gs = []
            for j in range(P):
                xc, ns, a = _apply_block(cfg, pattern[j], gp[j], xc,
                                         positions,
                                         gs[j] if gs is not None else None,
                                         mode, max_len=max_len, paged=paged)
                new_gs.append(ns)
                aux = aux + a
            return (xc, aux), new_gs

        body = group_body
        if cfg.remat and mode == "train":
            body = jax.checkpoint(group_body,
                                  policy=jax.checkpoint_policies.nothing_saveable)
        scan_states = states["scan"] if states is not None else None
        (x, aux_total), new_scan_states = jax.lax.scan(
            body, (x, aux_total), (layers["scan"], scan_states))
    else:
        new_scan_states = states["scan"] if states is not None else []

    new_rem = []
    for j in range(rem):
        blk = pattern[n_groups * P + j]
        st = states["rem"][j] if states is not None else None
        def blk_fn(p_, x_, st_, blk=blk):
            return _apply_block(cfg, blk, p_, x_, positions, st_, mode,
                                max_len=max_len, paged=paged)
        if cfg.remat and mode == "train":
            blk_fn = jax.checkpoint(
                blk_fn, policy=jax.checkpoint_policies.nothing_saveable)
        x, ns, a = blk_fn(layers["rem"][j], x, st)
        new_rem.append(ns)
        aux_total = aux_total + a
    new_states = None
    if mode != "train":
        new_states = {"scan": new_scan_states, "rem": new_rem}
    return x, new_states, aux_total


def _flat_layers(cfg, layers, x, positions, states, mode, max_len=None,
                 paged=None):
    pattern = cfg.layer_pattern()
    aux_total = jnp.zeros((), jnp.float32)
    new_states = []
    for i, blk in enumerate(pattern):
        st = states["flat"][i] if states is not None else None
        x, ns, a = _apply_block(cfg, blk, layers["flat"][i], x, positions,
                                st, mode, max_len=max_len, paged=paged)
        new_states.append(ns)
        aux_total = aux_total + a
    return x, ({"flat": new_states} if mode != "train" else None), aux_total


def forward(params, cfg, inputs: dict, mode: str = "train",
            states: Optional[dict] = None,
            max_len: Optional[int] = None) -> dict:
    """Run the model.

    train         : inputs {tokens[, patches]}   -> {hidden, aux}
    prefill       : inputs {tokens[, patches]}   -> {last_logits, states, aux}
    decode        : inputs {tokens} + states     -> {logits, states}
    paged_prefill : inputs {tokens (1,C), start (), block_table (W,)}
                    + paged states               -> {chunk_logits, states}
    paged_decode  : inputs {tokens (B,1), block_tables (B,W), lengths (B,)}
                    + paged states               -> {logits, states}
    """
    paged = None
    if mode == "decode":
        # positions come from the per-layer state's pos counter
        pos0 = _first_pos(states)
        x = embed_inputs(params, cfg, inputs, pos0)
    elif mode == "paged_prefill":
        paged = {"block_table": inputs["block_table"],
                 "start": inputs["start"]}
        x = embed_inputs(params, cfg, inputs, inputs["start"])
        pos0 = None
    elif mode == "paged_decode":
        paged = {"block_tables": inputs["block_tables"],
                 "lengths": inputs["lengths"]}
        x = embed_inputs(params, cfg, inputs, inputs["lengths"][:, None])
        pos0 = None
    else:
        x = embed_inputs(params, cfg, inputs, 0)
        pos0 = None
    S = x.shape[1]
    if mode == "decode":
        positions = pos0 + jnp.arange(1)
    else:
        # paged modes compute absolute positions inside the attention layer
        # (from start / lengths); this drives nothing there.
        positions = jnp.arange(S)

    run = _scan_layers if cfg.scan_layers else _flat_layers
    x, new_states, aux = run(cfg, params["layers"], x, positions, states,
                             mode, max_len=max_len, paged=paged)

    out: Dict[str, Any] = {"aux": aux}
    if mode == "train":
        out["hidden"] = x
    elif mode == "prefill":
        out["last_logits"] = apply_head(params, cfg, x[:, -1:])[:, 0]
        out["states"] = new_states
    elif mode == "paged_prefill":
        out["chunk_logits"] = apply_head(params, cfg, x)   # (1, C, V...)
        out["states"] = new_states
    else:
        out["logits"] = apply_head(params, cfg, x)[:, 0]
        out["states"] = new_states
    return out


def _first_pos(states):
    leaves = jax.tree_util.tree_leaves(
        jax.tree_util.tree_map(lambda s: s["pos"], states,
                               is_leaf=lambda s: isinstance(s, dict)
                               and "pos" in s))
    p = leaves[0]
    return p[0] if p.ndim == 1 else p  # scanned states carry a group axis


def config_for_shape(cfg, shape):
    """Long-context decode on full-attention archs switches to the
    beyond-paper sliding-window variant (weights are unchanged)."""
    if (shape.kind == "decode" and shape.seq_len > 65536
            and not cfg.is_subquadratic()):
        raise ValueError(
            f"{cfg.name} cannot serve {shape.name}: full attention and no "
            "long_context_window configured (see DESIGN.md skips)")
    if (shape.kind == "decode" and shape.seq_len > 65536
            and cfg.long_context_window is not None
            and cfg.sliding_window is None):
        return dataclasses.replace(cfg,
                                   sliding_window=cfg.long_context_window)
    return cfg
