"""Mixture-of-Experts FFN with capacity-bounded dispatch.

Two execution paths share the same weights and (capacity) semantics:

* ``_apply_moe_local`` — single-device reference: FIFO capacity selection
  per expert via gather, used on CPU (smoke tests) and as the oracle.

* ``_apply_moe_sharded`` — the TPU adaptation (see DESIGN.md).  Key
  observation: with activations sharded over the data axis and *replicated*
  over the model axis, expert parallelism needs NO all-to-all: every model
  shard already holds the tokens, so shard j simply computes its owned
  expert slice(s) on its local tokens and one reduce(-scatter)/psum over
  'model' combines the top-k expert outputs.  Capacity is enforced per
  token-shard (C_loc = cf * T_loc * K / E), the standard local-capacity
  approximation.  When E < model-axis size (Mixtral 8e on 16-way TP) the
  model axis factors into (expert_parallel=gcd(E, M), ffn_parallel=M/gcd):
  each expert's FFN is column-split over ffn_parallel shards and the same
  psum accumulates the partial products.

This replaces a GSPMD scatter-based dispatch that replicated the
(T*K, d) dispatch tensors on every device (measured 15 x 12.9 GB/device
on dbrx-132b train_4k — see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.launch import sharding
from repro.models.layers import dense_init

# shard_map moved to the jax namespace (and check_rep -> check_vma) in
# newer releases; support both so the multidevice paths run everywhere.
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _SHARD_MAP_KW = {"check_vma": False}
else:                                              # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
    _SHARD_MAP_KW = {"check_rep": False}


def init_moe(key, cfg, dtype) -> dict:
    m = cfg.moe
    d, f, E = cfg.d_model, cfg.d_ff, m.num_experts
    ks = jax.random.split(key, 4)
    p = {
        "router": dense_init(ks[0], d, E, jnp.float32),
        "we_up": jnp.stack([dense_init(k, d, f, dtype)
                            for k in jax.random.split(ks[1], E)]),
        "we_down": jnp.stack([dense_init(k, f, d, dtype)
                              for k in jax.random.split(ks[2], E)]),
    }
    if cfg.gated_ffn:
        p["we_gate"] = jnp.stack([dense_init(k, d, f, dtype)
                                  for k in jax.random.split(ks[3], E)])
    return p


# ---------------------------------------------------------------------------
# routing pieces shared by both paths
# ---------------------------------------------------------------------------


def _route(router_w, cfg, xf):
    """xf (T, d) -> (gate_dense (T, E) f32, aux scalar)."""
    m = cfg.moe
    E, K = m.num_experts, m.top_k
    logits = xf.astype(jnp.float32) @ router_w
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    # dense (T, E) combine weights: w[t, e] = gate_k if idx_k == e else 0
    onehot = jax.nn.one_hot(idx, E, dtype=probs.dtype)       # (T, K, E)
    w_dense = jnp.einsum("tk,tke->te", gate, onehot)
    # Switch-style load-balance aux loss
    density = jnp.mean(onehot[:, 0], axis=0)
    density_proxy = jnp.mean(probs, axis=0)
    aux = jnp.sum(density * density_proxy) * E * m.router_aux_weight
    return w_dense, aux


def _expert_ffn(xb, wu, wd, wg):
    up = xb @ wu
    h = jax.nn.silu(xb @ wg) * up if wg is not None else jax.nn.gelu(up)
    return h @ wd


def _capacity(cfg, T_loc: int) -> int:
    m = cfg.moe
    c = int(math.ceil(m.capacity_factor * T_loc * m.top_k / m.num_experts))
    return max(min(c, T_loc), 1)


def _one_expert(xf, w_col, wu, wd, wg, C: int):
    """Capacity-bounded FIFO compute of one expert on local tokens.

    xf (T, d); w_col (T,) combine weights; returns (T, d) contribution.
    """
    T = xf.shape[0]
    assigned = w_col > 0
    # FIFO priority: earlier tokens win capacity slots
    priority = jnp.where(assigned, T - jnp.arange(T), 0)
    _, tok_idx = jax.lax.top_k(priority, C)
    valid = assigned[tok_idx]
    xb = xf[tok_idx] * valid[:, None].astype(xf.dtype)
    yb = _expert_ffn(xb, wu, wd, wg)
    yb = yb * (w_col[tok_idx] * valid).astype(xf.dtype)[:, None]
    out = jnp.zeros_like(xf)
    return out.at[tok_idx].add(yb)


# ---------------------------------------------------------------------------
# single-device reference path
# ---------------------------------------------------------------------------


def _apply_moe_local(p, cfg, xf) -> Tuple[jnp.ndarray, jnp.ndarray]:
    w_dense, aux = _route(p["router"], cfg, xf)
    C = _capacity(cfg, xf.shape[0])
    out = jnp.zeros_like(xf)
    for e in range(cfg.moe.num_experts):
        wg = p["we_gate"][e] if "we_gate" in p else None
        out = out + _one_expert(xf, w_dense[:, e], p["we_up"][e],
                                p["we_down"][e], wg, C)
    return out, aux


# ---------------------------------------------------------------------------
# sharded path (expert parallel over the 'model' axis, no all-to-all)
# ---------------------------------------------------------------------------


def _layout_dims(cfg, M: int):
    E = cfg.moe.num_experts
    e_par = math.gcd(E, M)
    f_par = M // e_par
    r = E // e_par                      # experts per expert-parallel shard
    f_lp = cfg.d_ff // f_par
    return e_par, f_par, r, f_lp


def layout_cols(w, cfg, M):
    """(..., E, d, f) -> (..., M, r, d, f_lp)."""
    e_par, f_par, r, f_lp = _layout_dims(cfg, M)
    lead = w.shape[:-3]
    d = w.shape[-2]
    w = w.reshape(*lead, e_par, r, d, f_par, f_lp)
    perm = tuple(range(len(lead))) + tuple(
        len(lead) + i for i in (0, 3, 1, 2, 4))
    return w.transpose(perm).reshape(*lead, M, r, d, f_lp)


def layout_rows(w, cfg, M):
    """(..., E, f, d) -> (..., M, r, f_lp, d)."""
    e_par, f_par, r, f_lp = _layout_dims(cfg, M)
    lead = w.shape[:-3]
    d = w.shape[-1]
    w = w.reshape(*lead, e_par, r, f_par, f_lp, d)
    perm = tuple(range(len(lead))) + tuple(
        len(lead) + i for i in (0, 2, 1, 3, 4))
    return w.transpose(perm).reshape(*lead, M, r, f_lp, d)


def layout_cols_inv(w, cfg, M):
    """Inverse of layout_cols (for accumulated gradients)."""
    e_par, f_par, r, f_lp = _layout_dims(cfg, M)
    lead = w.shape[:-4]
    d = w.shape[-2]
    w = w.reshape(*lead, e_par, f_par, r, d, f_lp)
    perm = tuple(range(len(lead))) + tuple(
        len(lead) + i for i in (0, 2, 3, 1, 4))
    return w.transpose(perm).reshape(*lead, cfg.moe.num_experts, d,
                                     cfg.d_ff)


def layout_rows_inv(w, cfg, M):
    e_par, f_par, r, f_lp = _layout_dims(cfg, M)
    lead = w.shape[:-4]
    d = w.shape[-1]
    w = w.reshape(*lead, e_par, f_par, r, f_lp, d)
    perm = tuple(range(len(lead))) + tuple(
        len(lead) + i for i in (0, 2, 1, 3, 4))
    return w.transpose(perm).reshape(*lead, cfg.moe.num_experts,
                                     cfg.d_ff, d)


def prepare_tree(params, cfg, M: int):
    """Hoisted layout: transform every MoE weight in the params tree once
    (outside the layer x microbatch loops).  Detected downstream by the
    extra leading M dim."""
    def walk(node):
        if isinstance(node, dict):
            if "we_up" in node:
                out = dict(node)
                out["we_up"] = layout_cols(node["we_up"], cfg, M)
                if "we_gate" in node:
                    out["we_gate"] = layout_cols(node["we_gate"], cfg, M)
                out["we_down"] = layout_rows(node["we_down"], cfg, M)
                return out
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, list):
            return [walk(v) for v in node]
        return node
    return walk(params)


def unprepare_grads(grads, cfg, M: int):
    """Inverse transform for gradients accumulated in hoisted layout."""
    def walk(node):
        if isinstance(node, dict):
            if "we_up" in node:
                out = dict(node)
                out["we_up"] = layout_cols_inv(node["we_up"], cfg, M)
                if "we_gate" in node:
                    out["we_gate"] = layout_cols_inv(node["we_gate"], cfg,
                                                     M)
                out["we_down"] = layout_rows_inv(node["we_down"], cfg, M)
                return out
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, list):
            return [walk(v) for v in node]
        return node
    return walk(grads)


def _apply_moe_sharded(p, cfg, xf, ctx) -> Tuple[jnp.ndarray, jnp.ndarray]:
    mesh = ctx.mesh
    M = mesh.shape["model"]
    e_par, f_par, r, f_lp = _layout_dims(cfg, M)

    if p["we_up"].ndim == 4:            # hoisted layout (M, r, d, f_lp)
        wu = p["we_up"]
        wg = p.get("we_gate")
        wd = p["we_down"]
    else:
        wu = layout_cols(p["we_up"], cfg, M)
        wg = layout_cols(p["we_gate"], cfg, M) if "we_gate" in p else None
        wd = layout_rows(p["we_down"], cfg, M)

    dp = ctx.rules.get("batch")
    tok_spec = P(dp, None)
    gated = wg is not None

    def body(x_loc, router_w, wu_l, wd_l, wg_l):
        # x_loc (T_loc, d); w*_l (1, r, ...) local expert slices
        w_dense, aux = _route(router_w, cfg, x_loc)
        C = _capacity(cfg, x_loc.shape[0])
        j = jax.lax.axis_index("model")
        my_e_par = j // f_par
        out = jnp.zeros_like(x_loc)
        for q in range(r):
            # weight layout from cols()/rows(): shard s owns experts
            # [s*r, s*r + r)  (C-order reshape over (e_par, r, ...))
            e = my_e_par * r + q
            w_col = jnp.take(w_dense, e, axis=1)
            out = out + _one_expert(
                x_loc, w_col, wu_l[0, q], wd_l[0, q],
                wg_l[0, q] if gated else None, C)
        out = jax.lax.psum(out, "model")
        # aux varies across token shards: globally mean it so the returned
        # scalar is replicated (out_specs P()).
        aux = jax.lax.pmean(aux, tuple(mesh.axis_names))
        return out, aux

    in_specs = (tok_spec, P(None, None), P("model"), P("model"))
    args = [xf, p["router"], wu, wd]
    if gated:
        in_specs = in_specs + (P("model"),)
        args.append(wg)
    else:
        in_specs = in_specs + (P(None),)
        args.append(jnp.zeros((M, r, 1, 1), xf.dtype))  # unused placeholder

    fn = _shard_map(
        body if gated else (lambda x, rw, a, b, c: body(x, rw, a, b, None)),
        mesh=mesh, in_specs=in_specs,
        out_specs=(tok_spec, P()), **_SHARD_MAP_KW)
    out, aux = fn(*args)
    return out, aux[()] if aux.ndim else aux


def _apply_moe_stationary(p, cfg, xf, ctx) -> Tuple[jnp.ndarray,
                                                    jnp.ndarray]:
    """Weights-stationary serving path (decode-sized token counts).

    Expert weights stay fully sharded — expert-major on 'model', the d
    contraction dim on 'data' — and are NEVER gathered.  Instead the tiny
    token batch is all-gathered across the data axis (T x d bytes), every
    chip computes its (expert, d-slice) partial products, partial
    pre-activations psum over 'data', and outputs psum over both axes
    (disjoint d-slices + disjoint experts).  Per layer this replaces
    O(weights) collectives with O(tokens) ones — for dbrx decode_32k that
    is ~GB -> ~MB per step (EXPERIMENTS.md §Perf).
    """
    mesh = ctx.mesh
    M = mesh.shape["model"]
    D = mesh.shape["data"]
    e_par, f_par, r, f_lp = _layout_dims(cfg, M)
    d_model = xf.shape[-1]
    assert d_model % D == 0
    d_lp = d_model // D

    wu = p["we_up"] if p["we_up"].ndim == 4 else layout_cols(
        p["we_up"], cfg, M)
    wg = None
    if "we_gate" in p:
        wg = p["we_gate"] if p["we_gate"].ndim == 4 else layout_cols(
            p["we_gate"], cfg, M)
    wd = p["we_down"] if p["we_down"].ndim == 4 else layout_rows(
        p["we_down"], cfg, M)
    # split the d contraction dim across 'data': (M, r, d, f_lp) ->
    # (M, D, r, d_lp, f_lp); dim order puts both sharded dims in front.
    wu = wu.reshape(M, r, D, d_lp, f_lp).transpose(0, 2, 1, 3, 4)
    if wg is not None:
        wg = wg.reshape(M, r, D, d_lp, f_lp).transpose(0, 2, 1, 3, 4)
    wd = wd.reshape(M, r, f_lp, D, d_lp).transpose(0, 3, 1, 2, 4)

    dp = ctx.rules.get("batch")
    dp_axes = dp if isinstance(dp, tuple) else ((dp,) if dp else ())
    tok_spec = P(dp, None)
    gated = wg is not None

    def body(x_loc, router_w, wu_l, wd_l, wg_l):
        # x_loc (T_loc, d) -> gather the full token set (tiny).  Gathering
        # minor-axis-first makes the final row index
        # (pod*D + data) * T_loc + t, matching the slice-back below.
        x_all = x_loc
        for ax in reversed(dp_axes):
            x_all = jax.lax.all_gather(x_all, ax, axis=0, tiled=True)
        T = x_all.shape[0]
        w_dense, aux = _route(router_w, cfg, x_all)
        C = _capacity(cfg, T)
        j = jax.lax.axis_index("model")
        i = jax.lax.axis_index("data")
        my_e_par = j // f_par
        di = i * d_lp
        x_slice = jax.lax.dynamic_slice_in_dim(x_all, di, d_lp, axis=1)

        out_full = jnp.zeros((T, d_model), jnp.float32)
        for q in range(r):
            e = my_e_par * r + q
            w_col = jnp.take(w_dense, e, axis=1)
            assigned = w_col > 0
            priority = jnp.where(assigned, T - jnp.arange(T), 0)
            _, tok_idx = jax.lax.top_k(priority, C)
            valid = assigned[tok_idx]
            xb = x_slice[tok_idx] * valid[:, None].astype(x_slice.dtype)
            # partial pre-activations over the local d-slice, then psum
            up = jax.lax.psum(xb @ wu_l[0, 0, q], "data")
            if gated:
                g = jax.lax.psum(xb @ wg_l[0, 0, q], "data")
                h = jax.nn.silu(g) * up
            else:
                h = jax.nn.gelu(up)
            yb = h @ wd_l[0, 0, q]                       # (C, d_lp)
            yb = yb * (w_col[tok_idx] * valid).astype(yb.dtype)[:, None]
            contrib = jnp.zeros((T, d_lp), jnp.float32)
            contrib = contrib.at[tok_idx].add(yb.astype(jnp.float32))
            out_full = jax.lax.dynamic_update_slice_in_dim(
                out_full,
                jax.lax.dynamic_slice_in_dim(out_full, di, d_lp, axis=1)
                + contrib, di, axis=1)
        # disjoint d-slices sum over 'data'; disjoint experts over 'model'
        out_full = jax.lax.psum(out_full, ("data", "model"))
        # slice back this shard's tokens
        T_loc = x_loc.shape[0]
        row = i
        if "pod" in mesh.axis_names and "pod" in dp_axes:
            row = jax.lax.axis_index("pod") * D + i
        start = row * T_loc if dp_axes else 0
        out_loc = jax.lax.dynamic_slice_in_dim(out_full, start, T_loc,
                                               axis=0)
        aux = jax.lax.pmean(aux, tuple(mesh.axis_names))
        return out_loc.astype(x_loc.dtype), aux

    in_specs = (tok_spec, P(None, None), P("model", "data"),
                P("model", "data"),
                P("model", "data") if gated else P(None))
    args = [xf, p["router"], wu, wd,
            wg if gated else jnp.zeros((M, D, 1, 1, 1), xf.dtype)]
    fn = _shard_map(
        body if gated else (lambda x, rw, a, b, c: body(x, rw, a, b, None)),
        mesh=mesh, in_specs=in_specs, out_specs=(tok_spec, P()),
        **_SHARD_MAP_KW)
    out, aux = fn(*args)
    return out, aux[()] if aux.ndim else aux


def apply_moe(p: dict, cfg, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    B, S, d = x.shape
    xf = x.reshape(B * S, d)
    ctx = sharding.current()
    if ctx is None or "model" not in ctx.mesh.axis_names:
        out, aux = _apply_moe_local(p, cfg, xf)
    elif (cfg.moe_stationary_serve and "data" in ctx.mesh.axis_names
          and B * S <= cfg.moe_stationary_max_tokens):
        out, aux = _apply_moe_stationary(p, cfg, xf, ctx)
    else:
        out, aux = _apply_moe_sharded(p, cfg, xf, ctx)
    return out.reshape(B, S, d), aux
