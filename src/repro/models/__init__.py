from repro.models.transformer import (abstract_params, apply_head,
                                      config_for_shape, embed_inputs,
                                      forward, init_layer_states, init_params)

__all__ = [
    "abstract_params", "apply_head", "config_for_shape", "embed_inputs",
    "forward", "init_layer_states", "init_params",
]
