"""RecurrentGemma / Griffin recurrent block: conv1d + RG-LRU.

Block structure (arXiv:2402.19427):
    x -> [gate branch: W_gate -> GeLU] ---------------------\
    x -> [main branch: W_in -> causal depthwise conv1d       * -> W_out
          -> RG-LRU diagonal recurrence] --------------------/

RG-LRU (real-gated linear recurrent unit), all diagonal / elementwise:
    r_t = sigmoid(block_diag(W_a) u_t)          recurrence gate
    i_t = sigmoid(block_diag(W_x) u_t)          input gate
    log a_t = -c * softplus(Lambda) * r_t       (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

The diagonal-linear form admits a parallel prefix-scan evaluation; the
baseline uses lax.scan (sequential, as Griffin's TPU reference does) and
``use_assoc_scan=True`` switches to lax.associative_scan — the beyond-paper
perf lever exercised in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

_C = 8.0
_NBLOCKS = 8  # block-diagonal gate projections, as in Griffin


def init_rglru(key, cfg, dtype) -> dict:
    d = cfg.d_model
    rd = cfg.rg_lru_dim or d
    bs = rd // _NBLOCKS
    ks = jax.random.split(key, 7)
    return {
        "w_in": dense_init(ks[0], d, rd, dtype),
        "w_gate_in": dense_init(ks[1], d, rd, dtype),
        "w_out": dense_init(ks[2], rd, d, dtype),
        "conv_w": (jax.random.normal(ks[3], (cfg.conv1d_width, rd))
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((rd,), dtype),
        # block-diagonal gate weights (nblocks, bs, bs), float32
        "gate_a": jax.random.normal(ks[4], (_NBLOCKS, bs, bs)) * (bs ** -0.5),
        "gate_x": jax.random.normal(ks[5], (_NBLOCKS, bs, bs)) * (bs ** -0.5),
        # Lambda init so that a ~ U[0.9, 0.999] at r=1 (Griffin appendix)
        "lam": jax.random.uniform(ks[6], (rd,), jnp.float32, 2.0, 5.0),
    }


def init_rglru_state(cfg, batch: int, make=jnp.zeros):
    rd = cfg.rg_lru_dim or cfg.d_model
    return {
        "h": make((batch, rd), jnp.float32),
        "conv": make((batch, cfg.conv1d_width - 1, rd), jnp.float32),
        "pos": make((), jnp.int32),
    }


def _block_diag(w, x):
    """x (..., rd) @ block_diag(w (nb, bs, bs)) -> (..., rd), float32."""
    nb, bs, _ = w.shape
    xs = x.reshape(*x.shape[:-1], nb, bs)
    out = jnp.einsum("...nb,nbc->...nc", xs.astype(jnp.float32), w)
    return out.reshape(*x.shape)


def _gates(p, u):
    """u (..., rd) float32 -> (log_a, gated input) elementwise terms."""
    r = jax.nn.sigmoid(_block_diag(p["gate_a"], u))
    i = jax.nn.sigmoid(_block_diag(p["gate_x"], u))
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * u)
    return a, b


def _conv1d(p, u):
    """Causal depthwise conv over (B, S, rd)."""
    W = p["conv_w"].shape[0]
    pad = jnp.pad(u, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + u.shape[1]] * p["conv_w"][i] for i in range(W))
    return out + p["conv_b"]


def rglru_scan(p, cfg, x, *, use_assoc_scan: bool = False
               ) -> Tuple[jnp.ndarray, dict]:
    """x (B, S, d) -> ((B, S, d), final state)."""
    gate = jax.nn.gelu(x @ p["w_gate_in"])
    u_raw = (x @ p["w_in"]).astype(jnp.float32)
    u = _conv1d(p, u_raw.astype(x.dtype)).astype(jnp.float32)
    a, b = _gates(p, u)
    if use_assoc_scan:
        # h_t = a_t h_{t-1} + b_t  ==  prefix scan over (a, b) pairs
        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, bl * ar + br
        _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    else:
        def step(hprev, ab):
            at, bt = ab
            h_new = at * hprev + bt
            return h_new, h_new
        B, S, rd = u.shape
        _, hs = jax.lax.scan(step, jnp.zeros((B, rd), jnp.float32),
                             (a.swapaxes(0, 1), b.swapaxes(0, 1)))
        h = hs.swapaxes(0, 1)
    out = h.astype(x.dtype) * gate
    Wc = p["conv_w"].shape[0]
    S = x.shape[1]
    conv_hist = jnp.pad(u_raw, ((0, 0), (Wc - 1, 0), (0, 0)))[:, S:S + Wc - 1]
    state = {"h": h[:, -1], "conv": conv_hist,
             "pos": jnp.asarray(S, jnp.int32)}
    return out @ p["w_out"], state


def rglru_step(p, cfg, x, state) -> Tuple[jnp.ndarray, dict]:
    """One decode step.  x (B, 1, d)."""
    xt = x[:, 0]
    gate = jax.nn.gelu(xt @ p["w_gate_in"])
    u_in = (xt @ p["w_in"]).astype(jnp.float32)
    # causal conv via the rolling buffer of the last (W-1) inputs
    hist = jnp.concatenate([state["conv"], u_in[:, None]], axis=1)
    W = p["conv_w"].shape[0]
    u = sum(hist[:, i] * p["conv_w"][i].astype(jnp.float32) for i in range(W))
    u = u + p["conv_b"].astype(jnp.float32)
    a, b = _gates(p, u)
    h = a * state["h"] + b
    out = (h.astype(x.dtype) * gate) @ p["w_out"]
    new_state = {"h": h, "conv": hist[:, 1:], "pos": state["pos"] + 1}
    return out[:, None], new_state
