"""Shared neural-net layers: norms, rotary/sinusoidal positions, MLPs.

Everything is functional: ``init_*`` returns a param pytree, ``apply_*``
consumes it.  Params are plain nested dicts of jnp arrays so they stack
cleanly for scan-over-layers and shard via PartitionSpec rules keyed on
path names (see repro/launch/sharding.py).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------


def dense_init(key, in_dim: int, out_dim: int, dtype) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale
            ).astype(dtype)


def init_rmsnorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# positions
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies, shape (head_dim // 2,)."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10_000.0) -> jnp.ndarray:
    """Rotary embedding.

    x: (..., seq, heads, head_dim); positions: (..., seq) or (seq,).
    """
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                     # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    # broadcast over the heads axis
    angles = angles[..., :, None, :]                        # (..., S, 1, hd/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(positions: jnp.ndarray, d_model: int) -> jnp.ndarray:
    """Classic transformer sinusoidal table; positions (...,) -> (..., d)."""
    half = d_model // 2
    freqs = jnp.exp(-math.log(10_000.0) * jnp.arange(half, dtype=jnp.float32)
                    / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# feed-forward
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, gated: bool, dtype) -> dict:
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], d_model, d_ff, dtype),
         "w_down": dense_init(ks[1], d_ff, d_model, dtype)}
    if gated:
        p["w_gate"] = dense_init(ks[2], d_model, d_ff, dtype)
    return p


def apply_mlp(p: dict, x: jnp.ndarray, *, dp_spec=None) -> jnp.ndarray:
    """Dense MLP.  Gated -> SwiGLU; non-gated -> squared-ReLU (Nemotron/
    StarCoder2 style approximated with gelu for smoothness)."""
    up = x @ p["w_up"]
    if dp_spec is not None:
        up = jax.lax.with_sharding_constraint(up, dp_spec)
    if "w_gate" in p:
        h = jax.nn.silu(x @ p["w_gate"]) * up
    else:
        h = jax.nn.gelu(up)
    return h @ p["w_down"]
