"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

Faithful to arXiv:2405.04517 at the cell level (exponential gating with the
max-stabiliser trick); simplifications vs the release code are noted inline.
Both cells expose:
  * ``*_scan``  — full-sequence recurrence via lax.scan (train / prefill)
  * ``*_step``  — single-token update (decode); state is the "KV cache"
    equivalent, O(1) in sequence length -> long_500k is in-family.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(key, cfg, dtype) -> dict:
    d, H, hd = cfg.d_model, cfg.num_heads, cfg.head_dim
    ks = jax.random.split(key, 8)
    return {
        "w_up": dense_init(ks[0], d, 2 * d, dtype),    # -> [z gate | y]
        "wq": dense_init(ks[1], d, H * hd, dtype),
        "wk": dense_init(ks[2], d, H * hd, dtype),
        "wv": dense_init(ks[3], d, H * hd, dtype),
        "wi": dense_init(ks[4], d, H, jnp.float32),    # scalar gates / head
        "wf": dense_init(ks[5], d, H, jnp.float32),
        "bf": jnp.ones((H,), jnp.float32) * 3.0,       # forget-bias init
        "w_down": dense_init(ks[6], d, d, dtype),
    }


def init_mlstm_state(cfg, batch: int, make=jnp.zeros):
    H, hd = cfg.num_heads, cfg.head_dim
    return {
        "C": make((batch, H, hd, hd), jnp.float32),
        "n": make((batch, H, hd), jnp.float32),
        "m": make((batch, H), jnp.float32),
        "pos": make((), jnp.int32),
    }


def _mlstm_cell(state, qkv_if):
    """One stabilised mLSTM step.  All inputs per-timestep (B, ...)."""
    C, n, m = state
    q, k, v, i_t, f_t = qkv_if            # q,k,v (B,H,hd); gates (B,H)
    m_new = jnp.maximum(f_t + m, i_t)
    f_p = jnp.exp(f_t + m - m_new)[..., None]
    i_p = jnp.exp(i_t - m_new)[..., None]
    C_new = f_p[..., None] * C + i_p[..., None] * (v[..., :, None]
                                                   * k[..., None, :])
    n_new = f_p * n + i_p * k
    num = jnp.einsum("bhij,bhj->bhi", C_new, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", n_new, q)), 1.0)
    h = num / den[..., None]
    return (C_new, n_new, m_new), h


def _mlstm_qkv(p, cfg, y):
    B = y.shape[0]
    rest = y.shape[1:-1]
    H, hd = cfg.num_heads, cfg.head_dim
    shape = (B, *rest, H, hd)
    q = (y @ p["wq"]).reshape(shape)
    k = (y @ p["wk"]).reshape(shape) / jnp.sqrt(jnp.asarray(hd, y.dtype))
    v = (y @ p["wv"]).reshape(shape)
    yf = y.astype(jnp.float32)
    i_t = yf @ p["wi"]
    f_t = yf @ p["wf"] + p["bf"]
    return q, k, v, i_t, f_t


def mlstm_scan(p, cfg, x) -> Tuple[jnp.ndarray, dict]:
    """x (B, S, d) -> ((B, S, d), final state)."""
    B, S, d = x.shape
    up = x @ p["w_up"]
    z, y = jnp.split(up, 2, axis=-1)
    q, k, v, i_t, f_t = _mlstm_qkv(p, cfg, y)

    def step(state, ins):
        return _mlstm_cell(state, ins)

    H, hd = cfg.num_heads, cfg.head_dim
    s0 = (jnp.zeros((B, H, hd, hd), jnp.float32),
          jnp.zeros((B, H, hd), jnp.float32),
          jnp.zeros((B, H), jnp.float32))
    xs = tuple(a.swapaxes(0, 1).astype(jnp.float32)
               for a in (q, k, v)) + (i_t.swapaxes(0, 1), f_t.swapaxes(0, 1))
    (C, n, m), hs = jax.lax.scan(step, s0, xs)
    h = hs.swapaxes(0, 1).reshape(B, S, d).astype(x.dtype)
    state = {"C": C, "n": n, "m": m, "pos": jnp.asarray(S, jnp.int32)}
    return (h * jax.nn.silu(z)) @ p["w_down"], state


def mlstm_step(p, cfg, x, state) -> Tuple[jnp.ndarray, dict]:
    """x (B, 1, d), state dict -> (out (B,1,d), new state)."""
    B, _, d = x.shape
    up = x[:, 0] @ p["w_up"]
    z, y = jnp.split(up, 2, axis=-1)
    q, k, v, i_t, f_t = _mlstm_qkv(p, cfg, y)
    (C, n, m), h = _mlstm_cell(
        (state["C"], state["n"], state["m"]),
        (q.astype(jnp.float32), k.astype(jnp.float32),
         v.astype(jnp.float32), i_t, f_t))
    h = h.reshape(B, d).astype(x.dtype)
    out = ((h * jax.nn.silu(z)) @ p["w_down"])[:, None]
    return out, {"C": C, "n": n, "m": m, "pos": state["pos"] + 1}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
# NOTE: the release code uses block-diagonal recurrent matrices (one block
# per head); we keep full d x d recurrence for clarity — the cell dynamics
# (exponential gating + normaliser + stabiliser) are unchanged.


def init_slstm(key, cfg, dtype) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 9)
    p = {}
    for idx, g in enumerate("ifzo"):
        p[f"w_{g}"] = dense_init(ks[idx], d, d, dtype)
        p[f"r_{g}"] = dense_init(ks[4 + idx], d, d, dtype)
    p["bf"] = jnp.ones((d,), jnp.float32) * 3.0
    p["w_out"] = dense_init(ks[8], d, d, dtype)
    return p


def init_slstm_state(cfg, batch: int, make=jnp.zeros):
    d = cfg.d_model
    z = lambda: make((batch, d), jnp.float32)  # noqa: E731
    return {"c": z(), "n": z(), "h": z(), "m": z(), "pos": make((), jnp.int32)}


def _slstm_cell(p, state, x_t):
    """x_t (B, d) float32."""
    c, n, h, m = state
    pre = lambda g: x_t @ p[f"w_{g}"].astype(jnp.float32) + \
        h @ p[f"r_{g}"].astype(jnp.float32)  # noqa: E731
    i_t = pre("i")
    f_t = pre("f") + p["bf"]
    z_t = jnp.tanh(pre("z"))
    o_t = jax.nn.sigmoid(pre("o"))
    m_new = jnp.maximum(f_t + m, i_t)
    i_p = jnp.exp(i_t - m_new)
    f_p = jnp.exp(f_t + m - m_new)
    c_new = f_p * c + i_p * z_t
    n_new = f_p * n + i_p
    h_new = o_t * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new), h_new


def slstm_scan(p, cfg, x) -> Tuple[jnp.ndarray, dict]:
    B, S, d = x.shape
    z = lambda: jnp.zeros((B, d), jnp.float32)  # noqa: E731
    s0 = (z(), z(), z(), z())

    def step(state, x_t):
        return _slstm_cell(p, state, x_t)

    (c, n, h_f, m), hs = jax.lax.scan(step, s0,
                                      x.swapaxes(0, 1).astype(jnp.float32))
    h = hs.swapaxes(0, 1).astype(x.dtype)
    state = {"c": c, "n": n, "h": h_f, "m": m,
             "pos": jnp.asarray(S, jnp.int32)}
    return h @ p["w_out"], state


def slstm_step(p, cfg, x, state) -> Tuple[jnp.ndarray, dict]:
    (c, n, h, m), h_new = _slstm_cell(
        p, (state["c"], state["n"], state["h"], state["m"]),
        x[:, 0].astype(jnp.float32))
    out = (h_new.astype(x.dtype) @ p["w_out"])[:, None]
    return out, {"c": c, "n": n, "h": h, "m": m, "pos": state["pos"] + 1}
