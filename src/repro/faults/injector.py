"""Deterministic per-seed fault schedules for a live edge cluster.

A :class:`FaultInjector` turns a list of :class:`FaultEvent` entries into
the state transitions the cluster applies while it runs: hard crashes
(engine DOWN, in-flight work orphaned), transient stalls (engine frozen
for a window), sustained slowdowns (engine steps at a fraction of its
rate), and recoveries after a downtime window.  Event times are
CLUSTER-RELATIVE seconds — the same timebase as ``Request.arrival_s`` in
a replayed trace — so one schedule means the same thing across runs and
machines.

Schedules are data, not randomness: :meth:`FaultInjector.from_spec`
expands a compact :class:`FaultSpec` into concrete events with
``numpy.random.default_rng(seed)``, so a chaos run is exactly
reproducible given (spec, seed) and two injectors built from the same
spec/seed fire identical schedules.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable, List, Optional, Sequence

import numpy as np

KINDS = ("crash", "stall", "slowdown", "recover")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault transition on one engine.

    ``duration_s`` auto-schedules the matching recovery (``inf`` = the
    engine never comes back on its own); ``factor`` is the slowdown
    stride — a ``slowdown`` engine serves one step out of ``factor``.
    """

    t_s: float
    engine: int
    kind: str
    duration_s: float = math.inf
    factor: int = 2

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"options: {KINDS}")
        if self.t_s < 0 or self.duration_s <= 0:
            raise ValueError("fault times/durations must be positive")
        if self.factor < 1:
            raise ValueError("slowdown factor must be >= 1")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Compact description of a random chaos schedule.

    Counts are totals across the cluster; times are drawn uniformly in
    ``[0.05, 0.75] * horizon_s`` so faults land mid-trace with room for
    recovery, and crash/slowdown windows last ``downtime_frac`` /
    ``slow_frac`` of the horizon.
    """

    crashes: int = 1
    stalls: int = 0
    slowdowns: int = 0
    downtime_frac: float = 0.25
    stall_frac: float = 0.08
    slow_frac: float = 0.3
    slow_factor: int = 3


class FaultInjector:
    """Replays a fault schedule against a cluster clock.

    The cluster polls :meth:`due` with its run-relative time; each event
    fires exactly once, in time order.  ``reset()`` rewinds the schedule
    so the same injector can replay an identical chaos run for another
    scheduler.
    """

    def __init__(self, events: Iterable[FaultEvent], num_engines: int,
                 seed: Optional[int] = None):
        evs: List[FaultEvent] = []
        for ev in events:
            if not 0 <= ev.engine < num_engines:
                raise ValueError(f"fault event targets engine {ev.engine}; "
                                 f"cluster has {num_engines}")
            evs.append(ev)
            if ev.kind in ("crash", "slowdown") and \
                    math.isfinite(ev.duration_s):
                evs.append(FaultEvent(t_s=ev.t_s + ev.duration_s,
                                      engine=ev.engine, kind="recover"))
        self.num_engines = num_engines
        self.seed = seed
        self.events = sorted(evs, key=lambda e: (e.t_s, e.engine, e.kind))
        self._next = 0

    # ------------------------------------------------------------------
    @classmethod
    def from_spec(cls, spec: FaultSpec, num_engines: int, horizon_s: float,
                  seed: int = 0) -> "FaultInjector":
        """Deterministically expand a spec into a concrete schedule."""
        rng = np.random.default_rng(seed)
        events: List[FaultEvent] = []

        def times(n):
            return rng.uniform(0.05 * horizon_s, 0.75 * horizon_s, n)

        for t in times(spec.crashes):
            events.append(FaultEvent(
                t_s=float(t), engine=int(rng.integers(num_engines)),
                kind="crash",
                duration_s=float(spec.downtime_frac * horizon_s)))
        for t in times(spec.stalls):
            events.append(FaultEvent(
                t_s=float(t), engine=int(rng.integers(num_engines)),
                kind="stall",
                duration_s=float(spec.stall_frac * horizon_s)))
        for t in times(spec.slowdowns):
            events.append(FaultEvent(
                t_s=float(t), engine=int(rng.integers(num_engines)),
                kind="slowdown",
                duration_s=float(spec.slow_frac * horizon_s),
                factor=int(spec.slow_factor)))
        return cls(events, num_engines, seed=seed)

    # ------------------------------------------------------------------
    def due(self, now_s: float) -> List[FaultEvent]:
        """Events whose time has come, each returned exactly once."""
        out = []
        while self._next < len(self.events) and \
                self.events[self._next].t_s <= now_s:
            out.append(self.events[self._next])
            self._next += 1
        return out

    @property
    def exhausted(self) -> bool:
        return self._next >= len(self.events)

    def reset(self) -> None:
        """Rewind so the identical schedule replays from t=0."""
        self._next = 0

    def describe(self) -> List[dict]:
        """JSON-friendly schedule dump (for BENCH_chaos.json records)."""
        return [{"t_s": e.t_s, "engine": e.engine, "kind": e.kind,
                 "duration_s": (None if math.isinf(e.duration_s)
                                else e.duration_s),
                 "factor": e.factor}
                for e in self.events]


def single_crash(engine: int, t_s: float, downtime_s: float,
                 num_engines: int) -> FaultInjector:
    """The canonical chaos case: one hard mid-trace crash + recovery."""
    return FaultInjector(
        [FaultEvent(t_s=t_s, engine=engine, kind="crash",
                    duration_s=downtime_s)], num_engines)
