"""Fault injection, health states, and retry/recovery policy.

  * ``policy``   — engine ``Health`` states (HEALTHY/DEGRADED/DOWN) and
                   the :class:`RetryPolicy` (capped retries, exponential
                   backoff, per-request watchdog).
  * ``injector`` — deterministic-per-seed live fault schedules
                   (:class:`FaultInjector`: crash / stall / slowdown /
                   recover events on a cluster-relative clock).
  * ``simfault`` — the simulator twin: a per-ES Bernoulli up/down chain
                   (:class:`FaultParams`) with action masking and a
                   wrong-choice penalty inside the jitted episode scan.
"""
from repro.faults.injector import (FaultEvent, FaultInjector, FaultSpec,
                                   single_crash)
from repro.faults.policy import AVAILABILITY, Health, RetryPolicy
from repro.faults.simfault import (FaultParams, init_avail, mask_actions,
                                   step_avail)

__all__ = [
    "AVAILABILITY", "FaultEvent", "FaultInjector", "FaultParams",
    "FaultSpec", "Health", "RetryPolicy", "init_avail", "mask_actions",
    "single_crash", "step_avail",
]
