"""Fault process for the jitted ``repro.core.env`` episode scan.

The live cluster injects faults from a wall-clock schedule; the
simulator needs the same phenomenon as a MARKOV process it can scan
over: each edge server is an independent Bernoulli up/down chain, one
transition per time slot —

    up   -> down  w.p. ``p_down``
    down -> up    w.p. ``p_up``

so the stationary availability is ``p_up / (p_up + p_down)`` and the
mean downtime is ``1 / p_up`` slots.  The scan threads a float ``(B,)``
availability vector: DOWN servers stop draining their queues (Eqn 4's
``f`` term is gated), the observation grows a per-ES availability
column, and actions landing on a DOWN server are REMAPPED to the
least-loaded available one with ``penalty_s`` added to the task's delay
— the cost of discovering the failure and re-offloading, which is what
teaches a trained policy to read the availability features.

``FaultParams`` is a frozen dataclass so it can sit inside the frozen
``EnvParams`` exactly like ``qos_mix``; ``fault=None`` keeps every code
path byte-identical to the legacy environment.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class FaultParams:
    """Bernoulli up/down process + wrong-choice penalty for the sim."""

    p_down: float = 0.05      # per-slot P(healthy -> down)
    p_up: float = 0.5         # per-slot P(down -> recovered)
    penalty_s: float = 2.0    # delay added when the pick was DOWN

    def __post_init__(self):
        if not (0.0 <= self.p_down <= 1.0 and 0.0 < self.p_up <= 1.0):
            raise ValueError("p_down in [0,1] and p_up in (0,1] required")
        if self.penalty_s < 0:
            raise ValueError("penalty_s must be non-negative")

    @property
    def stationary_availability(self) -> float:
        return self.p_up / max(self.p_up + self.p_down, 1e-12)


def init_avail(num_bs: int) -> jnp.ndarray:
    """Every ES starts an episode healthy."""
    return jnp.ones((num_bs,), jnp.float32)


def step_avail(fp: FaultParams, avail: jnp.ndarray,
               u: jnp.ndarray) -> jnp.ndarray:
    """One Bernoulli up/down transition per ES (``u``: (B,) uniforms)."""
    up = avail > 0.5
    go_down = up & (u < fp.p_down)
    go_up = ~up & (u < fp.p_up)
    return jnp.where(go_down, 0.0,
                     jnp.where(go_up, 1.0, avail)).astype(jnp.float32)


def mask_actions(avail: jnp.ndarray, load: jnp.ndarray,
                 actions: jnp.ndarray):
    """Remap picks landing on DOWN servers to the least-loaded UP one.

    Returns ``(actions, wrong)`` where ``wrong`` flags the remapped
    picks (the wrong-choice penalty applies to exactly these).  When
    every server is down there is no right choice: picks stand
    unpenalised and the queue dynamics (no draining) carry the cost.
    """
    up = avail > 0.5
    any_up = up.any()
    fallback = jnp.argmin(jnp.where(up, load, jnp.inf)).astype(actions.dtype)
    wrong = (~up[actions]) & any_up
    return jnp.where(wrong, fallback, actions), wrong
