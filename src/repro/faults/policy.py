"""Engine health states and the request retry/watchdog policy.

Edge servers fail in kind, not just in degree (EAT, arXiv:2507.10026):
a hard crash strands every in-flight request, a transient stall merely
delays them, a sustained slowdown stretches the whole decode batch.  The
cluster reduces all of these to three health states:

``HEALTHY``
    Serving normally; fully available to the scheduler.
``DEGRADED``
    Alive but impaired (stalling or running slowed).  Still admits and
    serves requests — the availability observation reports 0.5 so a
    failure-aware policy can steer around it without hard-masking it.
``DOWN``
    Crashed.  In-flight lanes were drained, KV pages / dense slots
    reclaimed, and the orphaned requests handed back to the cluster for
    re-offloading.  The scheduler must not place here (availability 0).

:class:`RetryPolicy` owns the recovery-side knobs: how many placements a
request gets (``max_attempts``), how re-offloads back off
(``backoff_base_s * backoff_factor**(attempts-1)``), and the per-request
watchdog that ABANDONS requests whose deadline is hopeless so overload
degrades gracefully instead of collapsing.  Deadline-carrying requests
are abandoned once their elapsed time exceeds ``deadline_grace`` times
their budget; best-effort requests get a flat ``best_effort_timeout_s``.
Because the engine queues drain in priority/EDF order, best-effort
traffic starves first under overload and is therefore shed first — the
high-priority classes keep completing inside their deadlines.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional


class Health(enum.Enum):
    """Availability state of one serving engine."""

    HEALTHY = "healthy"
    DEGRADED = "degraded"
    DOWN = "down"


# Observation feature per health state (NaN-guarded into [0, 1]).
AVAILABILITY = {Health.HEALTHY: 1.0, Health.DEGRADED: 0.5,
                Health.DOWN: 0.0}


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Capped-retry + exponential-backoff + watchdog configuration."""

    max_attempts: int = 3            # total placements, first try included
    backoff_base_s: float = 0.05     # wait before the first re-offload
    backoff_factor: float = 2.0      # exponential growth per extra attempt
    deadline_grace: float = 2.0      # abandon past grace * deadline budget
    best_effort_timeout_s: float = 30.0   # watchdog for deadline-free work

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if self.backoff_base_s < 0 or self.backoff_factor < 1.0:
            raise ValueError("backoff must be non-negative and "
                             "non-shrinking")
        if self.deadline_grace < 1.0:
            raise ValueError("deadline_grace < 1 would abandon requests "
                             "that could still meet their deadline")

    def backoff_s(self, attempts: int) -> float:
        """Delay before re-offloading a request placed ``attempts`` times."""
        return (self.backoff_base_s
                * self.backoff_factor ** max(attempts - 1, 0))

    def hopeless(self, req, now: float) -> bool:
        """Watchdog verdict: is finishing this request pointless?

        ``now`` is on the same absolute clock as ``req.t_arrival`` (the
        cluster stamps arrivals on first submit, so retried requests are
        judged against their ORIGINAL arrival, not the retry time).
        """
        t0 = req.t_arrival if req.t_arrival is not None else req.t_enqueue
        if t0 is None:
            return False
        elapsed = now - t0
        budget: Optional[float] = req.deadline_budget_s
        if budget is not None:
            return elapsed > self.deadline_grace * budget
        return elapsed > self.best_effort_timeout_s
