"""Synthetic data pipeline for the training substrate.

Real corpora are out of scope for a dry-run environment, but the pipeline
is structured like a production one: a deterministic, seekable token
source per architecture family (restart-safe: step -> batch is a pure
function), modality frontends stubbed per the assignment ([audio] codebook
streams, [vlm] patch embeddings), and next-token labels with loss masks.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch: int = 8
    seq_len: int = 128
    seed: int = 0


def _rng_for_step(seed: int, step: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, step]))


def synth_batch(cfg: ModelConfig, dc: DataConfig, step: int) -> Dict:
    """Deterministic batch for ``step`` (pure function — checkpoint-safe).

    Tokens follow a patterned distribution (ramps + noise) rather than
    uniform noise so the CE loss has learnable structure — smoke training
    tests assert the loss actually falls.
    """
    rng = _rng_for_step(dc.seed, step)
    B, S, V = dc.batch, dc.seq_len, cfg.vocab_size

    def stream(shape):
        base = rng.integers(0, V, size=(shape[0],) + (1,) * (len(shape) - 1))
        ramp = np.cumsum(np.ones(shape, np.int64), axis=-1)
        noise = rng.integers(0, max(V // 64, 2), size=shape)
        return ((base + 3 * ramp + noise) % V).astype(np.int32)

    out: Dict = {}
    if cfg.num_codebooks:
        toks = stream((B, cfg.num_codebooks, S + 1))
        out["tokens"] = jnp.asarray(toks[..., :-1])
        out["labels"] = jnp.asarray(toks[..., 1:])
        return out

    if cfg.vision_patches:
        text_len = S - cfg.vision_patches
        assert text_len > 1, "seq_len must exceed vision_patches"
        toks = stream((B, text_len + 1))
        out["tokens"] = jnp.asarray(toks[:, :-1])
        out["patches"] = jnp.asarray(
            rng.standard_normal((B, cfg.vision_patches, cfg.vision_dim),
                                dtype=np.float32))
        # hidden layout = [patches | text]; labels shifted over full seq,
        # loss masked to text positions (patch targets are undefined)
        labels = np.zeros((B, S), np.int32)
        labels[:, cfg.vision_patches:] = toks[:, 1:]
        mask = np.zeros((B, S), np.float32)
        mask[:, cfg.vision_patches:] = 1.0
        out["labels"] = jnp.asarray(labels)
        out["mask"] = jnp.asarray(mask)
        return out

    toks = stream((B, S + 1))
    out["tokens"] = jnp.asarray(toks[:, :-1])
    out["labels"] = jnp.asarray(toks[:, 1:])
    return out


def batches(cfg: ModelConfig, dc: DataConfig,
            start_step: int = 0) -> Iterator[Dict]:
    step = start_step
    while True:
        yield synth_batch(cfg, dc, step)
        step += 1
