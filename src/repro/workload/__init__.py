"""Heterogeneous model zoo + QoS-class workload layer.

  * ``qos``        — QoS classes (priority weight, deadline budget,
                     quality-demand z_n range, model preference) and the
                     default interactive / standard / batch mix.
  * ``trace``      — mixed-class Poisson trace generation on top of
                     ``repro.cluster.request``.
  * ``queueing``   — priority/EDF engine queues (FIFO-compatible for
                     QoS-free workloads).
  * ``capability`` — per-engine capability descriptors: measured tok/s
                     as the live f_b', per-token Gcycles as rho_n.

The classes here are shared verbatim by the ``core.env`` simulator
(``EnvParams.qos_mix``) and live traces (``poisson_trace(qos_mix=...)``),
which is what keeps the extended Eqn-6 observation aligned across both
backends.
"""
from repro.workload.capability import (COLD_FLOPS, EngineCapability,
                                       cold_token_seconds)
from repro.workload.qos import (BEST_EFFORT, DEFAULT_MIX, INTERACTIVE,
                                STANDARD, QoSClass, QoSMix,
                                normalized_weights, priority_of, scaled)
from repro.workload.queueing import EDFQueue
from repro.workload.trace import qos_poisson_trace

__all__ = [
    "BEST_EFFORT", "COLD_FLOPS", "DEFAULT_MIX", "EDFQueue",
    "EngineCapability", "INTERACTIVE", "QoSClass", "QoSMix", "STANDARD",
    "cold_token_seconds", "normalized_weights", "priority_of",
    "qos_poisson_trace", "scaled",
]
