"""Heterogeneous trace generation: a QoS mix over the Poisson process.

Thin front-end over :func:`repro.cluster.request.poisson_trace` — the
mixing itself lives there (``qos_mix=``) so the cluster layer has no
dependency on this package.  This module picks sane derived defaults:
the trace-level ``max_new_tokens`` bound is the largest class z_n, and
per-class prompt lengths pass through the classes unchanged.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

from repro.cluster.request import Request, poisson_trace
from repro.workload.qos import DEFAULT_MIX, QoSClass


def qos_poisson_trace(num_requests: int, rate: float, prompt_len: int,
                      vocab_size: int, *,
                      mix: Sequence[Tuple[QoSClass, float]] = DEFAULT_MIX,
                      num_origins: int = 1, num_codebooks: int = 0,
                      seed: int = 0) -> List[Request]:
    """Poisson arrivals with per-request class, deadline and demand."""
    z_hi = max(c.z_range[1] for c, _ in mix)
    return poisson_trace(num_requests, rate, prompt_len,
                         max_new_tokens=z_hi, vocab_size=vocab_size,
                         num_origins=num_origins,
                         num_codebooks=num_codebooks, seed=seed,
                         qos_mix=tuple(mix))
