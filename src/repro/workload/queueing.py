"""Priority / earliest-deadline-first request queues for the engines.

``ServeEngine`` used to drain its backlog FIFO; with QoS classes the
admission order IS the intra-engine scheduling policy, so the queue
orders by

    (higher priority, earlier absolute deadline, FIFO arrival seq)

i.e. strict priority between classes and EDF inside a class.  Requests
without a QoS class all share priority 1.0 and no deadline, so a
QoS-free workload degrades to the exact FIFO order the pre-QoS engine
had (the tie-break sequence number preserves admission order).

The container mimics the small slice of the ``collections.deque`` API
the engine uses (``append`` / ``popleft`` / ``[0]`` peek / iteration /
``clear``), so it is a drop-in replacement.
"""
from __future__ import annotations

import heapq
import math
from typing import Iterator, List, Tuple


class EDFQueue:
    """Priority + EDF ordered queue of ``Request`` objects."""

    def __init__(self):
        self._heap: List[Tuple[float, float, int, object]] = []
        self._seq = 0

    @staticmethod
    def _key(req) -> Tuple[float, float]:
        qos = getattr(req, "qos", None)
        prio = float(getattr(qos, "priority", 1.0) or 1.0)
        deadline = getattr(req, "deadline_s", None)
        if deadline is None:
            deadline = math.inf
        return (-prio, float(deadline))

    def append(self, req) -> None:
        prio, deadline = self._key(req)
        heapq.heappush(self._heap, (prio, deadline, self._seq, req))
        self._seq += 1

    def popleft(self):
        if not self._heap:
            raise IndexError("pop from an empty EDFQueue")
        return heapq.heappop(self._heap)[-1]

    def __getitem__(self, i: int):
        if i != 0:
            raise IndexError("EDFQueue only exposes the head ([0])")
        return self._heap[0][-1]

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)

    def __iter__(self) -> Iterator:
        """Iterate queued requests (heap order, NOT pop order) — for
        aggregate backlog signals like ``pending_tokens``."""
        return (entry[-1] for entry in self._heap)

    def drain(self, pred) -> List[object]:
        """Remove and return every queued request matching ``pred``.

        The watchdog's shedding hook: surviving entries keep their
        original (priority, deadline, seq) keys, so relative order —
        including FIFO ties — is preserved exactly.
        """
        kept, out = [], []
        for entry in self._heap:
            (out if pred(entry[-1]) else kept).append(entry)
        if out:
            heapq.heapify(kept)
            self._heap = kept
        return [entry[-1] for entry in out]

    def clear(self) -> None:
        self._heap.clear()
        self._seq = 0
