"""QoS classes for heterogeneous AIGC workloads.

The paper models one anonymous task stream; real AIGC traffic is a mix
of service classes with very different latency contracts (EAT,
arXiv:2507.10026): an interactive image edit must land in a couple of
seconds, a batch render only cares about eventual completion.  A
:class:`QoSClass` packages the knobs one class needs:

  * ``priority``   — weight in priority-weighted goodput and in the
                     (optional) priority-weighted reward of the
                     simulator; also the first key of the engine-side
                     EDF queues (``repro.workload.queueing``).
  * ``deadline_s`` — service-delay budget from arrival to finish.
                     ``math.inf`` means best-effort (never missed).
  * ``z_range``    — the per-class quality-demand range: generated
                     tokens / denoising steps z_n (paper Eqn 2), so
                     interactive traffic is short and batch traffic
                     long.
  * ``prompt_len`` — optional per-class prompt length override
                     (mixed prompt-length distributions per class).
  * ``model_pref`` — optional preferred arch id; the live observation
                     inflates the affinity feature of engines serving
                     a different model (Joint Model Assignment,
                     arXiv:2409.09072).

Instances are frozen (hashable), so they can sit inside the frozen
``EnvParams`` and be shared verbatim between the simulator and a live
trace — the whole point: ONE class definition drives both backends.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class QoSClass:
    """One service class of the heterogeneous workload."""

    name: str
    priority: float = 1.0
    deadline_s: float = math.inf      # budget from arrival to finish
    z_range: Tuple[int, int] = (1, 16)
    prompt_len: Optional[int] = None
    model_pref: Optional[str] = None

    def __post_init__(self):
        if self.priority <= 0:
            raise ValueError(f"{self.name}: priority must be positive")
        if self.deadline_s <= 0:
            raise ValueError(f"{self.name}: deadline must be positive")
        lo, hi = self.z_range
        if not (0 < lo <= hi):
            raise ValueError(f"{self.name}: bad z_range {self.z_range}")

    @property
    def best_effort(self) -> bool:
        return math.isinf(self.deadline_s)


# Default three-tier mix (EAT-style interactive / standard / batch).
INTERACTIVE = QoSClass("interactive", priority=4.0, deadline_s=2.0,
                       z_range=(1, 8))
STANDARD = QoSClass("standard", priority=2.0, deadline_s=6.0,
                    z_range=(4, 16))
BEST_EFFORT = QoSClass("batch", priority=1.0, deadline_s=math.inf,
                       z_range=(8, 32))

# (class, mix weight) pairs; weights are normalised wherever consumed.
QoSMix = Tuple[Tuple[QoSClass, float], ...]
DEFAULT_MIX: QoSMix = ((INTERACTIVE, 0.4), (STANDARD, 0.4),
                       (BEST_EFFORT, 0.2))


def normalized_weights(mix: Sequence[Tuple[QoSClass, float]]):
    """Class list + probability vector for a (class, weight) mix."""
    classes = [c for c, _ in mix]
    w = [float(x) for _, x in mix]
    tot = sum(w)
    if tot <= 0:
        raise ValueError("qos mix weights must sum to a positive value")
    return classes, [x / tot for x in w]


def priority_of(req) -> float:
    """Priority weight of a request (1.0 when it carries no QoS class)."""
    qos = getattr(req, "qos", None)
    return float(getattr(qos, "priority", 1.0) or 1.0)


def scaled(cls: QoSClass, *, deadline_s: Optional[float] = None,
           z_range: Optional[Tuple[int, int]] = None,
           prompt_len: Optional[int] = None,
           model_pref: Optional[str] = None) -> QoSClass:
    """Benchmark helper: rescale a class to a scenario's time/token scale."""
    kw = {}
    if deadline_s is not None:
        kw["deadline_s"] = deadline_s
    if z_range is not None:
        kw["z_range"] = z_range
    if prompt_len is not None:
        kw["prompt_len"] = prompt_len
    if model_pref is not None:
        kw["model_pref"] = model_pref
    return dataclasses.replace(cls, **kw)
