"""Per-engine capability descriptors: the live f_b' / rho_n.

The paper parameterises heterogeneity through per-ES capacity f_b'
(Gcycles/s) and per-task computing density rho_n (Gcycles/step).  On a
live heterogeneous fleet those quantities are real and measurable:

  * ``rho_gcycles`` — per-generated-token cost of THIS engine's model,
    from the config's analytic active-parameter count (2 FLOPs/param
    per token): the model-complexity term the paper calls rho_n.
  * ``tok_s``       — measured decode throughput (1 / EWMA round time)
    once the engine has served anything, else an analytic cold prior:
    the live f_b'.

``EngineCapability`` is a snapshot; ``ServeEngine.capability`` builds a
fresh one on demand so ``tok_s`` tracks the EWMA.  The cluster's
extended observation derives its model-affinity feature from
``est_token_seconds`` (= 1 / tok_s).
"""
from __future__ import annotations

import dataclasses


# Nominal device throughput for the cold-start prior (FLOPs/s).  Only the
# RELATIVE cost across engines matters to the scheduler: the prior makes a
# 3B model look ~10x slower per token than a 350M one before any
# measurement exists, and the EWMA replaces it after the first round.
COLD_FLOPS = 25e9


@dataclasses.dataclass(frozen=True)
class EngineCapability:
    """Snapshot of one engine's serving capability."""

    arch: str                 # registry arch id (e.g. "qwen2-1.5b")
    model_name: str           # cfg.name (e.g. "qwen2-1.5b-smoke")
    num_layers: int
    d_model: int
    active_params: int        # params touched per generated token
    rho_gcycles: float        # per-token cost (Gcycles): live rho_n
    tok_s: float              # decode throughput (tokens/s): live f_b'
    measured: bool            # tok_s from EWMA (True) or cold prior
    paged: bool               # serves from the shared KV page pool
    # prefix caching (repro.serving.paged_kv): what fraction of this
    # engine's admissions reused cached prompt KV, and how many prompt
    # tokens it currently holds resident — the expected-prefix-hit
    # signal the prefix-affinity scheduler routes on (0 for dense /
    # cache-off engines)
    prefix_hit_rate: float = 0.0
    prefix_cached_tokens: int = 0

    @property
    def token_seconds(self) -> float:
        return 1.0 / max(self.tok_s, 1e-9)


def cold_token_seconds(cfg) -> float:
    """Analytic per-token decode time prior for an unmeasured engine."""
    return max(2.0 * cfg.active_param_count() / COLD_FLOPS, 1e-9)
