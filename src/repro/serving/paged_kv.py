"""Host-side page-pool accounting for paged KV serving.

The device side (repro.models.attention / kernels.decode_attention) sees
only arrays: per-layer pools (num_pages, KV, page_size, hd) and int32
block tables.  This module owns the *allocation* story:

``PagePool``
    A free-list over physical page ids 1..num_pages-1.  Page 0 is
    reserved as the null page — block-table padding, masked decode lanes
    and clamped overshoot writes all land there, so it is never handed
    out.  Pages are interchangeable (any page can back any logical
    position of any sequence), which is what makes the pool
    fragmentation-free: freeing a sequence returns its pages to the list
    and any later request can reuse them, regardless of allocation order.

``BlockTable``
    Per-sequence logical->physical page mapping.  ``row(width)`` pads the
    mapped pages with null-page zeros up to a fixed width so every lane's
    table has the same shape under jit; reads past the mapped range are
    masked by length, and chunked-prefill overshoot writes clamp onto the
    null padding.

The engine reserves worst-case pages at admission
(``pages_needed(prompt + max_new_tokens)``): generation length is
deterministic here, so reservation is exact and admitted requests can
never deadlock waiting for pages mid-generation.
"""
from __future__ import annotations

from typing import List


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


class PagePool:
    """Free-list allocator over physical KV pages.

    ``num_pages`` counts the whole pool *including* the reserved null
    page 0, matching the leading axis of the device-side pool arrays.
    """

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 2:
            raise ValueError("need at least one allocatable page + null")
        if page_size < 1:
            raise ValueError("page_size must be positive")
        self.num_pages = num_pages
        self.page_size = page_size
        # LIFO: recently freed (cache-warm) pages are reused first
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self._allocated: set = set()

    @property
    def num_free(self) -> int:
        return len(self._free)

    def pages_needed(self, tokens: int) -> int:
        return cdiv(max(tokens, 0), self.page_size)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int) -> List[int]:
        if n > len(self._free):
            raise RuntimeError(
                f"page pool exhausted: want {n}, have {len(self._free)}")
        pages = [self._free.pop() for _ in range(n)]
        self._allocated.update(pages)
        return pages

    def free(self, pages: List[int]) -> None:
        for p in pages:
            if p not in self._allocated:
                raise RuntimeError(f"double free / foreign page {p}")
            self._allocated.discard(p)
            self._free.append(p)

    def reset(self) -> None:
        self._free = list(range(self.num_pages - 1, 0, -1))
        self._allocated.clear()


class BlockTable:
    """One sequence's logical->physical page list."""

    def __init__(self, pool: PagePool, tokens: int):
        self.pool = pool
        self.pages: List[int] = pool.alloc(pool.pages_needed(tokens))

    def row(self, width: int) -> List[int]:
        """Fixed-width table row, null-padded (page 0) past the mapping."""
        if len(self.pages) > width:
            raise ValueError(
                f"{len(self.pages)} pages do not fit a width-{width} row")
        return self.pages + [0] * (width - len(self.pages))

    def release(self) -> None:
        if self.pages:
            self.pool.free(self.pages)
            self.pages = []


def paged_supported(cfg) -> bool:
    """Whether a config can be served from a shared page pool.

    Requires every block to be full (unwindowed) attention with a
    model-dtype cache; recurrent mixers, ring-buffer windows and int8
    caches keep the dense per-slot path.
    """
    if cfg.kv_cache_dtype == "int8":
        return False
    if getattr(cfg, "vision_patches", 0):
        return False
    return all(b.mixer == "attn" and b.window is None
               for b in cfg.layer_pattern())
