"""Host-side page-pool accounting for paged KV serving.

The device side (repro.models.attention / kernels.decode_attention) sees
only arrays: per-layer pools (num_pages, KV, page_size, hd) and int32
block tables.  This module owns the *allocation* story:

``PagePool``
    A REFCOUNTED free-list over physical page ids 1..num_pages-1.  Page 0
    is reserved as the null page — block-table padding, masked decode
    lanes and clamped overshoot writes all land there, so it is never
    handed out.  Pages are interchangeable (any page can back any logical
    position of any sequence), which is what makes the pool
    fragmentation-free.  ``alloc`` hands out pages at refcount 1;
    ``retain`` lets a second holder (another sequence sharing a prompt
    prefix, or the prefix cache itself) pin the same physical page, and
    ``release``/``free`` decrement — a page returns to the free list only
    when its last holder lets go, so sharing can never free memory out
    from under a live sequence.

``BlockTable``
    Per-sequence logical->physical page mapping.  ``row(width)`` pads the
    mapped pages with null-page zeros up to a fixed width so every lane's
    table has the same shape under jit; reads past the mapped range are
    masked by length, and chunked-prefill overshoot writes clamp onto the
    null padding.  A table may be constructed over a prefix of SHARED
    pages (already retained for it by the caller) followed by freshly
    allocated private pages.

``PrefixCache``
    A content-addressed index over completed full prompt blocks (the
    vLLM-style automatic-prefix-caching map).  Keys are chained block
    hashes — ``key_b = H(key_{b-1} || tokens of block b)`` — so a lookup
    walks the new prompt's blocks and reuses every page whose entire
    token-chain-so-far matches a cached one.  Each entry holds ONE pool
    reference on its page; matching sequences take their own reference on
    top (copy-free sharing), and a lane that diverges *mid-block* forks
    the partially-matching cached page copy-on-write instead (the engine
    copies the page device-side and re-prefills only the divergent tail).
    Entries are evicted leaf-first in LRU order under pool pressure;
    eviction only drops the cache's reference — a page still referenced
    by a live lane survives until that lane releases it.

The engine reserves worst-case pages at admission
(``pages_needed(prompt + max_new_tokens)``): generation length is
deterministic here, so reservation is exact and admitted requests can
never deadlock waiting for pages mid-generation.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Set

import numpy as np


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


class PagePool:
    """Refcounted free-list allocator over physical KV pages.

    ``num_pages`` counts the whole pool *including* the reserved null
    page 0, matching the leading axis of the device-side pool arrays.
    """

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 2:
            raise ValueError("need at least one allocatable page + null")
        if page_size < 1:
            raise ValueError("page_size must be positive")
        self.num_pages = num_pages
        self.page_size = page_size
        # LIFO: recently freed (cache-warm) pages are reused first
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self._refs: Dict[int, int] = {}

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def total_refs(self) -> int:
        """Sum of refcounts over allocated pages — the engine's KV-leak
        accounting: when idle, every remaining reference must belong to
        the prefix cache (one per entry), so ``total_refs - cache.size``
        is the leak."""
        return sum(self._refs.values())

    def refcount(self, page: int) -> int:
        return self._refs.get(page, 0)

    def pages_needed(self, tokens: int) -> int:
        return cdiv(max(tokens, 0), self.page_size)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int) -> List[int]:
        if n > len(self._free):
            raise RuntimeError(
                f"page pool exhausted: want {n}, have {len(self._free)}")
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._refs[p] = 1
        return pages

    def retain(self, pages: Sequence[int]) -> None:
        """Add one reference per page (prefix sharing / cache pin)."""
        for p in pages:
            if p not in self._refs:
                raise RuntimeError(f"retain of unallocated page {p}")
            self._refs[p] += 1

    def release(self, pages: Sequence[int]) -> None:
        """Drop one reference per page; a page returns to the free list
        only when its LAST holder releases it."""
        for p in pages:
            if p not in self._refs:
                raise RuntimeError(f"double free / foreign page {p}")
            self._refs[p] -= 1
            if self._refs[p] == 0:
                del self._refs[p]
                self._free.append(p)

    # historical name (pre-refcount API); identical to one release
    free = release

    def reset(self) -> None:
        self._free = list(range(self.num_pages - 1, 0, -1))
        self._refs.clear()


class BlockTable:
    """One sequence's logical->physical page list.

    ``shared`` pages (a matched prompt prefix) must already carry a
    reference taken for THIS table; the remainder is allocated fresh.
    ``release`` drops one reference on every page — shared pages whose
    other holders (the prefix cache, sibling lanes) remain stay resident.
    """

    def __init__(self, pool: PagePool, tokens: int,
                 shared: Sequence[int] = ()):
        self.pool = pool
        need = pool.pages_needed(tokens)
        shared = list(shared)
        if len(shared) > need:
            raise ValueError(
                f"{len(shared)} shared pages exceed the {need}-page "
                f"mapping for {tokens} tokens")
        self.pages: List[int] = shared + pool.alloc(need - len(shared))

    def row(self, width: int) -> List[int]:
        """Fixed-width table row, null-padded (page 0) past the mapping."""
        if len(self.pages) > width:
            raise ValueError(
                f"{len(self.pages)} pages do not fit a width-{width} row")
        return self.pages + [0] * (width - len(self.pages))

    def release(self) -> None:
        if self.pages:
            self.pool.release(self.pages)
            self.pages = []


# ---------------------------------------------------------------------------
# content-addressed prefix index
# ---------------------------------------------------------------------------

_ROOT = b"repro-prefix-root"


def _position_major(prompt) -> np.ndarray:
    """(1, S) tokens or (1, K, S) audio -> (S, F) with position leading,
    so a byte prefix of j rows is exactly the first j token positions."""
    arr = np.asarray(prompt)
    arr = np.moveaxis(arr, -1, 0)
    return np.ascontiguousarray(arr.reshape(arr.shape[0], -1))


def _block_key(parent: bytes, block: np.ndarray) -> bytes:
    h = hashlib.blake2b(parent, digest_size=16)
    h.update(block.tobytes())
    return h.digest()


def _common_positions(a: bytes, b: bytes, bpp: int) -> int:
    """Length (in positions) of the common prefix of two position-major
    byte strings; ``bpp`` bytes per position."""
    n = min(len(a), len(b)) // bpp
    j = 0
    while j < n and a[j * bpp:(j + 1) * bpp] == b[j * bpp:(j + 1) * bpp]:
        j += 1
    return j


@dataclasses.dataclass
class _Entry:
    page: int
    key: bytes
    parent: bytes
    tok: bytes        # the block's position-major token bytes (full block)


@dataclasses.dataclass
class PrefixMatch:
    """Result of matching a prompt against the cache (a peek — nothing is
    retained or LRU-bumped until :meth:`PrefixCache.acquire`)."""

    pages: List[int]                  # fully-matched block pages, in order
    keys: List[bytes]                 # their chain keys
    cow_page: Optional[int] = None    # cached page to fork copy-on-write
    cow_key: Optional[bytes] = None
    cow_tokens: int = 0               # matched positions inside that block

    @property
    def tokens(self) -> int:
        """Total reusable prompt tokens (full blocks + partial fork)."""
        return len(self.pages) * self._page_size + self.cow_tokens

    _page_size: int = 0


class PrefixCache:
    """LRU map from token-block chains to resident KV pages.

    Each entry pins its page with one pool reference, so completed
    prompts stay resident after their request finishes; under pool
    pressure :meth:`ensure_free` evicts LEAF entries (no cached children
    — evicting mid-chain would strand descendants) in LRU order.
    Eviction drops only the cache's reference: a page still shared with
    a live lane is never freed by eviction.
    """

    def __init__(self, pool: PagePool):
        self.pool = pool
        self._entries: "OrderedDict[bytes, _Entry]" = OrderedDict()
        self._children: Dict[bytes, Set[bytes]] = {}
        self.evictions = 0
        self.insertions = 0

    @property
    def size(self) -> int:
        return len(self._entries)

    # -- lookup --------------------------------------------------------
    def match(self, prompt, max_tokens: Optional[int] = None) -> PrefixMatch:
        """Longest cached chain matching the prompt (pure peek).

        Walks full blocks while the chained hash hits; then scans the
        last matched node's cached children for the longest in-block
        token prefix — the copy-on-write fork point for a lane that
        diverges mid-block.  ``max_tokens`` caps the usable match (the
        engine passes ``prompt_len - 1`` so at least one position is
        always re-prefilled to produce next-token logits).
        """
        arr = _position_major(prompt)
        S = arr.shape[0]
        bpp = arr.shape[1] * arr.dtype.itemsize
        ps = self.pool.page_size
        limit = S if max_tokens is None else max(min(S, int(max_tokens)), 0)

        parent = _ROOT
        pages: List[int] = []
        keys: List[bytes] = []
        b = 0
        while (b + 1) * ps <= limit:
            key = _block_key(parent, arr[b * ps:(b + 1) * ps])
            e = self._entries.get(key)
            if e is None:
                break
            pages.append(e.page)
            keys.append(key)
            parent = key
            b += 1
        m = PrefixMatch(pages=pages, keys=keys, _page_size=ps)

        rem = limit - b * ps
        if rem > 0:
            tail = np.ascontiguousarray(arr[b * ps:min((b + 1) * ps, S)]
                                        ).tobytes()
            best_j, best = 0, None
            for ck in self._children.get(parent, ()):
                e = self._entries[ck]
                j = min(_common_positions(e.tok, tail, bpp), rem)
                if j > best_j:
                    best_j, best = j, e
            if best is not None:
                m.cow_page, m.cow_key, m.cow_tokens = (best.page, best.key,
                                                       best_j)
        return m

    def acquire(self, m: PrefixMatch) -> None:
        """Commit a match: retain every matched page (including the COW
        source — the engine releases it after forking) and bump LRU."""
        for k in m.keys:
            if k in self._entries:
                self._entries.move_to_end(k)
        if m.cow_key is not None and m.cow_key in self._entries:
            self._entries.move_to_end(m.cow_key)
        self.pool.retain(m.pages)
        if m.cow_page is not None:
            self.pool.retain([m.cow_page])

    def release_match(self, m: PrefixMatch) -> None:
        """Undo :meth:`acquire` for an admission that did not go through."""
        self.pool.release(m.pages)
        if m.cow_page is not None:
            self.pool.release([m.cow_page])

    # -- insertion -----------------------------------------------------
    def insert(self, prompt, pages: Sequence[int]) -> int:
        """Cache every FULL prompt block of a lane that finished
        prefilling; returns the number of new entries.  Existing keys are
        LRU-bumped and keep their original page (the lane's duplicate
        page, if it prefilled one privately, stays private and is freed
        with the lane).  The cache takes one reference per new entry, so
        cached pages outlive the inserting request.
        """
        arr = _position_major(prompt)
        ps = self.pool.page_size
        parent = _ROOT
        added = 0
        for b in range(arr.shape[0] // ps):
            blk = np.ascontiguousarray(arr[b * ps:(b + 1) * ps])
            key = _block_key(parent, blk)
            if key in self._entries:
                self._entries.move_to_end(key)
            else:
                page = pages[b]
                self.pool.retain([page])
                self._entries[key] = _Entry(page=page, key=key,
                                            parent=parent, tok=blk.tobytes())
                self._children.setdefault(parent, set()).add(key)
                added += 1
                self.insertions += 1
            parent = key
        return added

    # -- eviction ------------------------------------------------------
    def _evict_one(self) -> bool:
        """Evict the LRU LEAF entry; returns False when nothing is
        evictable.  Only the cache's reference is dropped — a page a live
        lane still shares survives until that lane releases it."""
        for key in self._entries:                 # OrderedDict = LRU order
            if self._children.get(key):
                continue                          # mid-chain: keep
            e = self._entries.pop(key)
            sibs = self._children.get(e.parent)
            if sibs is not None:
                sibs.discard(key)
                if not sibs:
                    del self._children[e.parent]
            self.pool.release([e.page])
            self.evictions += 1
            return True
        return False

    def ensure_free(self, n: int) -> bool:
        """Evict cached leaves until the pool can allocate ``n`` pages;
        False when the cache runs out of evictable entries first."""
        while self.pool.num_free < n:
            if not self._evict_one():
                return False
        return True

    def clear(self) -> None:
        """Release every cached page (reset path)."""
        for e in self._entries.values():
            self.pool.release([e.page])
        self._entries.clear()
        self._children.clear()


def paged_supported(cfg) -> bool:
    """Whether a config can be served from a shared page pool.

    Requires every block to be full (unwindowed) attention with a
    model-dtype cache; recurrent mixers, ring-buffer windows and int8
    caches keep the dense per-slot path.
    """
    if cfg.kv_cache_dtype == "int8":
        return False
    if getattr(cfg, "vision_patches", 0):
        return False
    return all(b.mixer == "attn" and b.window is None
               for b in cfg.layer_pattern())
