"""Batched serving engine: the per-ES "DEdgeAI worker" (paper Fig. 10).

One engine wraps one model replica: jitted prefill + decode steps, a
fixed-batch decode loop, and per-request latency accounting.  The
edge-level scheduler (repro.core) decides WHICH engine serves a request;
the engine measures the serve-side pieces of Eqn (2): queueing + compute.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.train.steps import make_decode_step, make_prefill_step


@dataclasses.dataclass
class RequestResult:
    tokens: list
    prefill_s: float
    decode_s: float
    queue_s: float

    @property
    def total_s(self) -> float:
        return self.prefill_s + self.decode_s + self.queue_s


class ServeEngine:
    """Fixed-shape batched engine for one model replica."""

    def __init__(self, cfg, params, *, max_len: int = 256,
                 sample: bool = False, temperature: float = 1.0):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self._prefill = jax.jit(make_prefill_step(cfg, max_len=max_len))
        dec = make_decode_step(cfg, sample=sample, temperature=temperature)
        self._decode = jax.jit(dec)
        self._busy_until = 0.0   # wall-clock queue model (FCFS, Eqn 3)
        self.sample = sample

    # ------------------------------------------------------------------
    def generate(self, prompts: jnp.ndarray, num_tokens: int,
                 rng: Optional[jax.Array] = None,
                 patches: Optional[jnp.ndarray] = None) -> RequestResult:
        """prompts (B, S) [or (B, K, S) audio]; returns generated tokens
        (B, num_tokens) plus timing."""
        now = time.time()
        queue_s = max(0.0, self._busy_until - now)

        rng = rng if rng is not None else jax.random.key(0)
        batch = {"tokens": prompts}
        if patches is not None:
            batch["patches"] = patches
        t0 = time.time()
        logits, states = self._prefill(self.params, batch)
        logits.block_until_ready()
        t1 = time.time()

        def pick(lg, k):
            if self.sample:
                return jax.random.categorical(k, lg, axis=-1)
            return jnp.argmax(lg, axis=-1)

        toks = []
        tok = pick(logits, rng).astype(jnp.int32)
        multi = self.cfg.num_codebooks > 0
        for step in range(num_tokens):
            toks.append(tok)
            nxt = tok[..., None] if not multi else tok[..., None]
            rng, krng = jax.random.split(rng)
            args = (self.params, {"tokens": nxt}, states)
            if self.sample:
                logits, tok, states = self._decode(*args, rng=krng)
            else:
                logits, tok, states = self._decode(*args)
        jax.tree_util.tree_map(
            lambda x: x.block_until_ready() if hasattr(
                x, "block_until_ready") else x, states)
        t2 = time.time()

        self._busy_until = max(now, self._busy_until) + (t2 - t0)
        return RequestResult(tokens=[t.tolist() for t in toks],
                             prefill_s=t1 - t0, decode_s=t2 - t1,
                             queue_s=queue_s)

    # ------------------------------------------------------------------
    @property
    def pending_seconds(self) -> float:
        """Current queue depth in seconds (the scheduler's q_bef signal)."""
        return max(0.0, self._busy_until - time.time())


def serve_batch(engines: List[ServeEngine], assignments: List[int],
                prompts: List[jnp.ndarray], num_tokens: int
                ) -> List[RequestResult]:
    """Route each prompt to its assigned engine (FCFS per engine)."""
    return [engines[assignments[i]].generate(prompts[i][None], num_tokens)
            for i in range(len(prompts))]
