"""Continuous-batching serving engine: the per-ES "DEdgeAI worker".

One engine wraps one model replica and serves admitted requests with one
of two KV memory models:

**Dense slot pool** (fallback, any arch family).  A FIXED pool of
``kv_slots`` per-request caches, each ``max_len`` deep.  Each ``step()``
runs one blocking batch-1 prefill per joining request, then ONE batched
decode round (a jitted ``vmap`` over the per-slot caches).  Capacity is
``kv_slots`` concurrent requests, full stop — a 32-token request holds a
``max_len``-deep cache hostage for its whole lifetime.

**Paged page pool** (all-attention configs; auto-detected).  KV memory is
a single shared pool of fixed-size pages per layer (vLLM-style), and each
request holds only ``ceil((prompt + max_new_tokens) / page_size)`` pages
named by a per-request block table (see repro.serving.paged_kv).
Admission is gated on *free pages*, not free slots, so many short
requests can be in flight at once — up to ``max_lanes`` — inside the same
KV budget that gave the dense pool ``kv_slots``.  Prefill is CHUNKED:
each step advances every still-prefilling lane by one ``prefill_chunk``-
token chunk and then runs one decode round across the lanes that have
finished prefilling — a long prompt no longer blocks the decode batch,
it interleaves with it.  Worst-case pages are reserved at admission
(generation length is deterministic), so admitted requests never
deadlock waiting for memory.  ``prefill_chunk`` trades time-to-first-
token for interleaving granularity: smaller chunks give decode lanes
more frequent turns, larger chunks amortise the per-chunk gather.

Per-request latency is MEASURED, not modelled: the Request lifecycle
timestamps (queue / prefill / decode) decompose the serving-side terms of
the paper's Eqn (2) exactly, replacing the old ``_busy_until`` wall-clock
queue hack.  The edge-level scheduler (``repro.cluster``) decides WHICH
engine serves a request; the engine reports its backlog via
``pending_tokens`` / ``pending_seconds`` (the q_b signal of Eqn 3).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster.request import Request
from repro.faults.policy import AVAILABILITY, Health
from repro.serving.paged_kv import BlockTable, PagePool, cdiv, paged_supported
from repro.train.steps import (make_decode_step, make_paged_decode_step,
                               make_paged_prefill_step, make_prefill_step)
from repro.workload.capability import EngineCapability, cold_token_seconds
from repro.workload.queueing import EDFQueue


@dataclasses.dataclass
class RequestResult:
    """Batch-level result of the blocking :meth:`ServeEngine.generate`.

    For B == 1 the three phases decompose the request's wall time
    exactly.  For a batch they are aggregates — worst per-request queue
    wait (slot contention when B > kv_slots), summed prefill compute,
    and the shared decode span; per-request timestamps are available
    through the ``admit()``/``step()`` API instead."""

    tokens: list
    prefill_s: float
    decode_s: float
    queue_s: float

    @property
    def total_s(self) -> float:
        return self.prefill_s + self.decode_s + self.queue_s


@dataclasses.dataclass
class _Lane:
    """One in-flight request in the paged engine."""

    req: Request
    table: BlockTable
    prompt_len: int
    chunk_pos: int = 0       # next prompt position to prefill
    length: int = 0          # KV positions written so far
    last_tok: Optional[np.ndarray] = None

    @property
    def decoding(self) -> bool:
        return self.chunk_pos >= self.prompt_len


class ServeEngine:
    """Continuous-batching engine for one model replica."""

    def __init__(self, cfg, params, *, max_len: int = 256,
                 kv_slots: int = 4, sample: bool = False,
                 temperature: float = 1.0,
                 clock: Callable[[], float] = time.monotonic,
                 paged: Optional[bool] = None, page_size: int = 16,
                 num_pages: Optional[int] = None,
                 max_lanes: Optional[int] = None,
                 prefill_chunk: int = 64,
                 arch_id: Optional[str] = None):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.kv_slots = kv_slots
        self.sample = sample
        self.arch_id = arch_id or cfg.name
        self._clock = clock
        # priority/EDF ordering; exact FIFO for requests without QoS
        self._queue = EDFQueue()
        self._zero_tok = np.zeros(
            (1, cfg.num_codebooks) if cfg.num_codebooks else (1,), np.int32)
        self._rng = jax.random.key(0)
        self._ewma_tok_s = 0.0         # measured seconds per decode round
        self._next_rid = 0
        self.peak_inflight = 0
        # fault-tolerance state (repro.faults)
        self.health = Health.HEALTHY
        self.fail_reason: Optional[str] = None
        self._stall_until = 0.0        # DEGRADED: frozen until this clock
        self._slow_every = 1           # DEGRADED: serve 1 step out of k
        self._step_seq = 0

        self.paged = paged_supported(cfg) if paged is None else bool(paged)
        if self.paged:
            self.page_size = page_size
            self.prefill_chunk = prefill_chunk
            if num_pages is None:
                # same KV token budget the dense pool would hold, plus the
                # reserved null page — the win is sharing, not more memory
                num_pages = 1 + kv_slots * cdiv(max_len, page_size)
            self.num_pages = num_pages
            self.max_lanes = max_lanes or 2 * kv_slots
            # fixed jit-stable block-table width: a max_len request plus
            # null padding for chunked-prefill overshoot writes
            self._row_width = (cdiv(max_len, page_size)
                               + cdiv(prefill_chunk, page_size) + 1)
            self._pool = PagePool(num_pages, page_size)
            self._lanes: List[Optional[_Lane]] = [None] * self.max_lanes
            self._paged_states = None   # built lazily on first admission
            self._paged_prefill = jax.jit(make_paged_prefill_step(cfg))
            self._paged_decode = jax.jit(make_paged_decode_step(
                cfg, sample=sample, temperature=temperature))
        else:
            self._prefill = jax.jit(make_prefill_step(cfg, max_len=max_len))
            self._decode1 = make_decode_step(cfg, sample=sample,
                                             temperature=temperature)
            self._slots: List[Optional[Request]] = [None] * kv_slots
            self._last_tok: List[Optional[np.ndarray]] = [None] * kv_slots
            self._pool_states = None   # (slots, ...) stacked per-slot caches
            self._pool_decode = None
            self._insert = None

    # ------------------------------------------------------------------
    # continuous-batching core
    # ------------------------------------------------------------------
    def admit(self, req: Request) -> None:
        """Enqueue a request; it joins the decode batch when capacity
        (a dense slot, or a lane + enough free pages) opens up."""
        if self.health is Health.DOWN:
            raise RuntimeError(
                f"engine {getattr(self, 'engine_id', '?')} "
                f"({self.arch_id}) is DOWN"
                f"{f' ({self.fail_reason})' if self.fail_reason else ''}; "
                f"cannot admit request {req.rid}")
        req.t_enqueue = self._clock()
        req.engine_id = getattr(self, "engine_id", None)
        self._queue.append(req)

    def step(self) -> List[Request]:
        """One scheduling iteration; returns requests finished this step.

        A DOWN engine is inert.  A DEGRADED engine is either stalled
        (frozen until ``_stall_until``, then self-healing — a transient
        straggler) or slowed (serving one step out of ``_slow_every``
        until an explicit :meth:`recover`)."""
        if self.health is Health.DOWN:
            return []
        if self.health is Health.DEGRADED:
            now = self._clock()
            if now < self._stall_until:
                return []
            if self._stall_until and self._slow_every <= 1:
                self.recover()          # stall window elapsed
            else:
                self._stall_until = 0.0
                self._step_seq += 1
                if self._step_seq % self._slow_every:
                    return []
        if self.paged:
            return self._step_paged()
        return self._step_dense()

    def _step_dense(self) -> List[Request]:
        finished = []
        free = [i for i, r in enumerate(self._slots) if r is None]
        while free and self._queue:
            req = self._queue.popleft()
            i = free.pop(0)
            req.t_prefill_start = self._clock()
            batch = {"tokens": req.prompt}
            if req.patches is not None:
                batch["patches"] = req.patches
            logits, st = self._prefill(self.params, batch)
            tok = np.asarray(self._pick(logits))
            req.t_prefill_end = self._clock()
            req.tokens.append(tok)
            if len(req.tokens) >= req.max_new_tokens:
                req.finish(req.t_prefill_end)
                finished.append(req)
                free.insert(0, i)
                continue
            self._ensure_pool(st)
            self._pool_states = self._insert(self._pool_states, st,
                                             jnp.int32(i))
            self._slots[i] = req
            self._last_tok[i] = tok
        self._note_inflight(sum(r is not None for r in self._slots))

        active = [i for i, r in enumerate(self._slots) if r is not None]
        if active:
            toks = np.stack([t if t is not None else self._zero_tok
                             for t in self._last_tok])
            keys = jax.random.split(self._next_key(), self.kv_slots)
            t0 = self._clock()
            tok_all, self._pool_states = self._pool_decode(
                self.params, jnp.asarray(toks[..., None], jnp.int32),
                self._pool_states, keys)
            tok_all = np.asarray(tok_all)          # blocks until ready
            self._note_round(t0, len(active))
            now = self._clock()
            for i in active:
                req = self._slots[i]
                tk = tok_all[i]
                req.tokens.append(tk)
                self._last_tok[i] = tk
                if len(req.tokens) >= req.max_new_tokens:
                    req.finish(now)
                    finished.append(req)
                    self._slots[i] = None
        return finished

    # ------------------------------------------------------------------
    # paged step: page-gated admission, chunked prefill, decode round
    # ------------------------------------------------------------------
    def _step_paged(self) -> List[Request]:
        finished = []
        # 1. admission — head-of-line, gated on free pages (worst case
        # reserved up front) and a free lane.  The queue drains in
        # priority/EDF order (exact FIFO without QoS classes); no
        # skipping past the ordered head.
        free = [i for i, ln in enumerate(self._lanes) if ln is None]
        while free and self._queue:
            req = self._queue[0]
            total = self._prompt_len(req) + req.max_new_tokens
            need = self._pool.pages_needed(total)
            if need > self._row_width - 1 - cdiv(self.prefill_chunk,
                                                 self.page_size):
                raise ValueError(
                    f"request needs {need} pages > per-request capacity "
                    f"(max_len={self.max_len})")
            if not self._pool.can_alloc(need):
                break
            self._queue.popleft()
            i = free.pop(0)
            self._lanes[i] = _Lane(req=req,
                                   table=BlockTable(self._pool, total),
                                   prompt_len=self._prompt_len(req))
        self._note_inflight(sum(ln is not None for ln in self._lanes))

        # 2. one prefill chunk per still-prefilling lane
        self._ensure_paged_states()
        C = self.prefill_chunk
        for i, lane in enumerate(self._lanes):
            if lane is None or lane.decoding:
                continue
            req = lane.req
            if lane.chunk_pos == 0:
                req.t_prefill_start = self._clock()
            c0 = lane.chunk_pos
            chunk = np.asarray(req.prompt[..., c0:c0 + C])
            pad = C - chunk.shape[-1]
            if pad:
                widths = [(0, 0)] * (chunk.ndim - 1) + [(0, pad)]
                chunk = np.pad(chunk, widths)
            row = jnp.asarray(lane.table.row(self._row_width), jnp.int32)
            logits, self._paged_states = self._paged_prefill(
                self.params,
                {"tokens": jnp.asarray(chunk, jnp.int32),
                 "start": jnp.asarray(c0, jnp.int32), "block_table": row},
                self._paged_states)
            lane.chunk_pos = c0 + C
            lane.length = min(lane.chunk_pos, lane.prompt_len)
            if lane.decoding:                      # last chunk of prompt
                last = lane.prompt_len - 1 - c0
                tok = np.asarray(self._pick(logits[0, last][None]))
                req.t_prefill_end = self._clock()
                req.tokens.append(tok)
                lane.last_tok = tok
                if len(req.tokens) >= req.max_new_tokens:
                    req.finish(req.t_prefill_end)
                    finished.append(req)
                    self._free_lane(i)

        # 3. one decode round across the lanes that finished prefilling;
        # idle/prefilling lanes ride along masked (null table, length 0)
        active = [i for i, ln in enumerate(self._lanes)
                  if ln is not None and ln.decoding]
        if active:
            L, W = self.max_lanes, self._row_width
            toks = np.zeros((L,) + self._zero_tok.shape, np.int32)
            tables = np.zeros((L, W), np.int32)
            lengths = np.zeros((L,), np.int32)
            for i in active:
                lane = self._lanes[i]
                toks[i] = lane.last_tok
                tables[i] = lane.table.row(W)
                lengths[i] = lane.length
            if self.cfg.num_codebooks:
                tok_in = toks.transpose(0, 2, 1)   # (L,1,K) -> (L,K,1)
            else:
                tok_in = toks                      # (L,1)
            t0 = self._clock()
            _, tok_all, self._paged_states = self._paged_decode(
                self.params,
                {"tokens": jnp.asarray(tok_in, jnp.int32),
                 "block_tables": jnp.asarray(tables, jnp.int32),
                 "lengths": jnp.asarray(lengths, jnp.int32)},
                self._paged_states, self._next_key())
            tok_np = np.asarray(tok_all)           # blocks until ready
            self._note_round(t0, len(active))
            now = self._clock()
            for i in active:
                lane = self._lanes[i]
                req = lane.req
                tk = tok_np[i:i + 1]               # (1,) or (1, K)
                req.tokens.append(tk)
                lane.last_tok = tk
                lane.length += 1                   # decode wrote one KV
                if len(req.tokens) >= req.max_new_tokens:
                    req.finish(now)
                    finished.append(req)
                    self._free_lane(i)
        return finished

    def run_to_completion(self, max_steps: int = 1_000_000) -> List[Request]:
        """Step until queue and slots drain; returns finished requests."""
        done = []
        for _ in range(max_steps):
            if not self.has_work:
                break
            done += self.step()
        return done

    def reset(self) -> None:
        """Drop queued/in-flight work and measurement state.

        Device pool contents need no zeroing — every KV position is
        written before it is read — but the rate EWMA and the request-id
        counter must restart or a reused engine reports the previous
        run's backlog estimate and non-monotonic request ids."""
        self._queue.clear()
        self._ewma_tok_s = 0.0
        self._next_rid = 0
        self.peak_inflight = 0
        self.health = Health.HEALTHY
        self.fail_reason = None
        self._stall_until = 0.0
        self._slow_every = 1
        self._step_seq = 0
        if self.paged:
            self._lanes = [None] * self.max_lanes
            self._pool.reset()
        else:
            self._slots = [None] * self.kv_slots
            self._last_tok = [None] * self.kv_slots

    # ------------------------------------------------------------------
    # fault tolerance: health transitions (repro.faults)
    # ------------------------------------------------------------------
    def fail(self, reason: str = "injected crash") -> List[Request]:
        """Hard crash: mark DOWN, drain queued + in-flight requests and
        reclaim every KV page / dense slot they held.

        Returns the orphaned requests (queued first, then in-flight) so
        the cluster can re-offload them; their per-attempt state is NOT
        reset here — recovery policy belongs to the caller."""
        orphans: List[Request] = list(self._queue)
        self._queue.clear()
        if self.paged:
            for i, lane in enumerate(self._lanes):
                if lane is not None:
                    orphans.append(lane.req)
                    self._free_lane(i)
        else:
            for i, req in enumerate(self._slots):
                if req is not None:
                    orphans.append(req)
                    self._slots[i] = None
                    self._last_tok[i] = None
        self.health = Health.DOWN
        self.fail_reason = str(reason)
        return orphans

    def recover(self) -> None:
        """Return to HEALTHY after a crash, stall, or slowdown window."""
        self.health = Health.HEALTHY
        self.fail_reason = None
        self._stall_until = 0.0
        self._slow_every = 1

    def degrade(self, *, stall_s: float = 0.0, slow_every: int = 1,
                reason: str = "injected degradation") -> None:
        """Soft fault: freeze for ``stall_s`` seconds (transient
        straggler, self-healing) and/or serve only one step out of
        ``slow_every`` (sustained slowdown, until :meth:`recover`)."""
        if self.health is Health.DOWN:
            raise RuntimeError("cannot degrade a DOWN engine; recover it "
                               "first")
        self.health = Health.DEGRADED
        self.fail_reason = str(reason)
        if stall_s > 0:
            self._stall_until = self._clock() + stall_s
        self._slow_every = max(int(slow_every), 1)

    @property
    def available(self) -> bool:
        """Placement-eligible (DEGRADED still serves, DOWN does not)."""
        return self.health is not Health.DOWN

    @property
    def availability(self) -> float:
        """Observation feature: 1 healthy, 0.5 degraded, 0 down."""
        return AVAILABILITY[self.health]

    @property
    def kv_leak(self) -> int:
        """Outstanding KV reservations (pages, or busy dense slots).

        0 whenever the engine is idle — the crash-recovery invariant the
        chaos tests assert: a crash mid-prefill or mid-decode must return
        the accounting to zero."""
        if self.paged:
            return self.num_pages - 1 - self._pool.num_free
        return sum(r is not None for r in self._slots)

    def shed(self, pred) -> List[Request]:
        """Remove queued (not yet running) requests matching ``pred`` —
        the cluster watchdog's shedding hook."""
        return self._queue.drain(pred)

    # ------------------------------------------------------------------
    # backlog signals (the scheduler's q_b / Eqn-3 observation)
    # ------------------------------------------------------------------
    def _inflight_requests(self) -> List[Request]:
        if self.paged:
            return [ln.req for ln in self._lanes if ln is not None]
        return [r for r in self._slots if r is not None]

    @property
    def has_work(self) -> bool:
        return bool(self._queue) or bool(self._inflight_requests())

    @property
    def pending_tokens(self) -> int:
        """Tokens still to generate across queued + in-flight requests."""
        n = sum(r.max_new_tokens for r in self._queue)
        n += sum(r.max_new_tokens - len(r.tokens)
                 for r in self._inflight_requests())
        return n

    @property
    def pending_seconds(self) -> float:
        """Measured backlog estimate: pending tokens x EWMA token time."""
        return self.pending_tokens * self._ewma_tok_s

    @property
    def est_token_seconds(self) -> float:
        """Seconds per decode token: measured EWMA once the engine has run
        a round, else a FLOPs-based cold prior (the paper's rho_n / f_b)."""
        if self._ewma_tok_s > 0:
            return self._ewma_tok_s
        return cold_token_seconds(self.cfg)

    @property
    def capability(self) -> EngineCapability:
        """Snapshot of this engine as an edge-server capability descriptor:
        its live f_b' (measured tok/s) and per-step cost rho_n."""
        active = self.cfg.active_param_count()
        return EngineCapability(
            arch=self.arch_id,
            model_name=self.cfg.name,
            num_layers=self.cfg.num_layers,
            d_model=self.cfg.d_model,
            active_params=active,
            rho_gcycles=2.0 * active / 1e9,
            tok_s=1.0 / self.est_token_seconds,
            measured=self._ewma_tok_s > 0,
            paged=self.paged)

    # ------------------------------------------------------------------
    # blocking compatibility API
    # ------------------------------------------------------------------
    def generate(self, prompts: jnp.ndarray, num_tokens: int,
                 rng: Optional[jax.Array] = None,
                 patches: Optional[jnp.ndarray] = None) -> RequestResult:
        """prompts (B, S) [or (B, K, S) audio] -> (B,)-stacked tokens per
        generated step, plus measured timing (admit all, drain)."""
        if rng is not None:
            self._rng = rng
        reqs = []
        for b in range(prompts.shape[0]):
            reqs.append(Request(
                rid=self._next_rid, prompt=prompts[b:b + 1],
                max_new_tokens=max(num_tokens, 1),
                patches=None if patches is None else patches[b:b + 1]))
            self._next_rid += 1
            self.admit(reqs[-1])
        self.run_to_completion()
        toks = [np.concatenate([r.tokens[s] for r in reqs], axis=0)
                for s in range(max(num_tokens, 1))]
        t_dec0 = max(r.t_prefill_end for r in reqs)
        t_end = max(r.t_finish for r in reqs)
        return RequestResult(
            tokens=toks,
            prefill_s=sum(r.prefill_s for r in reqs),
            decode_s=max(t_end - t_dec0, 0.0),
            queue_s=max(max(r.queue_s for r in reqs), 0.0))

    # ------------------------------------------------------------------
    # internals
    # ------------------------------------------------------------------
    def _next_key(self):
        self._rng, k = jax.random.split(self._rng)
        return k

    def _pick(self, logits):
        if self.sample:
            return jax.random.categorical(self._next_key(), logits, axis=-1
                                          ).astype(jnp.int32)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    @staticmethod
    def _prompt_len(req: Request) -> int:
        return int(req.prompt.shape[-1])

    def _note_round(self, t0: float, active: int) -> None:
        # a round advances every active lane one token, so the per-token
        # drain rate is round time / active lanes
        dt = (self._clock() - t0) / active
        self._ewma_tok_s = (0.7 * self._ewma_tok_s + 0.3 * dt
                            if self._ewma_tok_s else dt)

    def _note_inflight(self, n: int) -> None:
        self.peak_inflight = max(self.peak_inflight, n)

    def _free_lane(self, i: int) -> None:
        self._lanes[i].table.release()
        self._lanes[i] = None

    def _ensure_paged_states(self) -> None:
        if self._paged_states is None:
            from repro.models.transformer import init_paged_states
            self._paged_states = init_paged_states(
                self.cfg, self.num_pages, self.page_size)

    def _ensure_pool(self, st):
        """Lazily build the slot pool + jitted batched decode from the
        structure of the first prefill's cache (covers every arch family:
        attention ring buffers, quantised caches, recurrent states)."""
        if self._pool_states is not None:
            return
        slots = self.kv_slots
        self._pool_states = jax.tree_util.tree_map(
            lambda leaf: jnp.zeros((slots,) + leaf.shape, leaf.dtype), st)
        self._insert = jax.jit(lambda pool, s, i: jax.tree_util.tree_map(
            lambda p_, s_: p_.at[i].set(s_), pool, s))
        dec, sample = self._decode1, self.sample

        def pool_step(params, toks, states, keys):
            def one(tk, st_, k):
                if sample:
                    _, tok, ns = dec(params, {"tokens": tk}, st_, rng=k)
                else:
                    _, tok, ns = dec(params, {"tokens": tk}, st_)
                return tok, ns

            return jax.vmap(one)(toks, states, keys)

        self._pool_decode = jax.jit(pool_step)


def serve_batch(engines: List[ServeEngine], assignments: List[int],
                prompts: List[jnp.ndarray], num_tokens: int
                ) -> List[RequestResult]:
    """Route each prompt to its assigned engine, serve them concurrently
    (continuous batching within each engine), return per-request results."""
    reqs = []
    for i, pr in enumerate(prompts):
        # prompts arrive unbatched — (S,) text or (K, S) audio — and gain
        # the leading batch dim here (matching the original serve_batch)
        req = Request(rid=i, prompt=pr[None],
                      max_new_tokens=max(num_tokens, 1))
        reqs.append(req)
        engines[assignments[i]].admit(req)
    while any(e.has_work for e in engines):
        for e in engines:
            if e.has_work:      # an idle engine's step() is not free:
                e.step()        # it still pays host-side bookkeeping
    return [RequestResult(tokens=r.tokens, prefill_s=r.prefill_s,
                          decode_s=r.decode_s, queue_s=r.queue_s)
            for r in reqs]
