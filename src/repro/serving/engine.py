"""Continuous-batching serving engine: the per-ES "DEdgeAI worker".

One engine wraps one model replica and serves admitted requests with one
of two KV memory models:

**Dense slot pool** (fallback, any arch family).  A FIXED pool of
``kv_slots`` per-request caches, each ``max_len`` deep.  Each ``step()``
runs one batch-1 prefill per joining request, then ONE batched decode
round (a jitted ``vmap`` over the per-slot caches).  Capacity is
``kv_slots`` concurrent requests, full stop — a 32-token request holds a
``max_len``-deep cache hostage for its whole lifetime.

**Paged page pool** (all-attention configs; auto-detected).  KV memory is
a single shared pool of fixed-size pages per layer (vLLM-style), and each
request holds only ``ceil((prompt + max_new_tokens) / page_size)`` pages
named by a per-request block table (see repro.serving.paged_kv).
Admission is gated on *free pages*, not free slots, so many short
requests can be in flight at once — up to ``max_lanes`` — inside the same
KV budget that gave the dense pool ``kv_slots``.  Prefill is CHUNKED:
each step advances every still-prefilling lane by one ``prefill_chunk``-
token chunk and then runs one decode round across the lanes that have
finished prefilling — a long prompt no longer blocks the decode batch,
it interleaves with it.  Worst-case pages are reserved at admission
(generation length is deterministic), so admitted requests never
deadlock waiting for memory.

**Overlapped stepping** (the fleet fast path).  ``step()`` is split into
a non-blocking :meth:`dispatch` — admit, enqueue this round's prefill
chunks + decode round on the device, and return WITHOUT any host sync —
and a :meth:`collect` that blocks on the round's results and finalizes
requests.  A cluster driver dispatches ALL engines before collecting ANY
(see ``EdgeCluster.step`` / :func:`serve_batch`), so E engines' decode
rounds execute concurrently on device instead of serializing E host
round-trips.  ``step() == dispatch(); collect()`` exactly: control flow
(admission order, slot/lane reuse, finish decisions) is resolved at
dispatch time from token COUNTS only, so tokens and terminal statuses
are bit-identical between serial and overlapped stepping.

**Shared compiled steps** (``repro.serving.compiled``).  The jitted
prefill/decode callables are fetched from a module-level cache keyed on
(config, shapes, mesh), so same-config engines in a fleet share one
executable instead of re-jitting per replica, and decode-round states
are donated (in-place pool update, no per-round copy).

**Sharded big-model engines.**  Pass ``mesh=`` (e.g.
``launch.mesh.make_smoke_mesh()`` on CPU CI or ``make_production_mesh()``
on real devices) and the engine places params via
``launch.sharding.param_shardings`` and its KV pool / recurrent states
via ``state_pspecs`` on that mesh, running every step inside the
corresponding :class:`~repro.launch.sharding.ShardingContext` — this is
how ``mixtral_8x22b`` / ``dbrx_132b`` scale configs serve.

Per-request latency is MEASURED, not modelled: the Request lifecycle
timestamps (queue / prefill / decode) decompose the serving-side terms of
the paper's Eqn (2) exactly.  The edge-level scheduler (``repro.cluster``)
decides WHICH engine serves a request; the engine reports its backlog via
``pending_tokens`` / ``pending_seconds`` (the q_b signal of Eqn 3).
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster.request import Request
from repro.faults.policy import AVAILABILITY, Health
from repro.launch import sharding as shlib
from repro.serving import compiled
from repro.serving.paged_kv import (BlockTable, PagePool, PrefixCache, cdiv,
                                    paged_supported)
from repro.workload.capability import EngineCapability, cold_token_seconds
from repro.workload.queueing import EDFQueue


@dataclasses.dataclass
class RequestResult:
    """Batch-level result of the blocking :meth:`ServeEngine.generate`.

    For B == 1 the three phases decompose the request's wall time
    exactly.  For a batch they are aggregates — worst per-request queue
    wait (slot contention when B > kv_slots), summed prefill compute,
    and the shared decode span; per-request timestamps are available
    through the ``admit()``/``step()`` API instead."""

    tokens: list
    prefill_s: float
    decode_s: float
    queue_s: float

    @property
    def total_s(self) -> float:
        return self.prefill_s + self.decode_s + self.queue_s


@dataclasses.dataclass
class _Lane:
    """One in-flight request in the paged engine."""

    req: Request
    table: BlockTable
    prompt_len: int
    chunk_pos: int = 0       # next prompt position to prefill
    length: int = 0          # KV positions written so far
    last_tok: Optional[np.ndarray] = None

    @property
    def decoding(self) -> bool:
        return self.chunk_pos >= self.prompt_len


@dataclasses.dataclass
class _Pending:
    """Device work enqueued by :meth:`ServeEngine.dispatch`, awaiting its
    :meth:`~ServeEngine.collect`.

    ``prefill`` holds ``(req, tok_device, pos, finished)`` — the deferred
    first-token of each prompt that completed prefilling this step
    (``pos`` is the token's index in ``req.tokens``); ``decode`` holds
    the round's stacked device tokens plus ``(slot/lane, req, pos,
    finished)`` per active participant.  Finish decisions are structural
    (token counts), so they are resolved at dispatch time; only VALUES
    and timestamps wait for the sync."""

    prefill: List[Tuple[Request, jax.Array, int, bool]] = \
        dataclasses.field(default_factory=list)
    decode: Optional[Tuple[jax.Array,
                           List[Tuple[int, Request, int, bool]]]] = None


class ServeEngine:
    """Continuous-batching engine for one model replica."""

    def __init__(self, cfg, params, *, max_len: int = 256,
                 kv_slots: int = 4, sample: bool = False,
                 temperature: float = 1.0,
                 clock: Callable[[], float] = time.monotonic,
                 paged: Optional[bool] = None, page_size: int = 16,
                 num_pages: Optional[int] = None,
                 max_lanes: Optional[int] = None,
                 prefill_chunk: int = 64,
                 prefix_cache: Optional[bool] = None,
                 arch_id: Optional[str] = None,
                 mesh=None):
        self.cfg = cfg
        self.max_len = max_len
        self.kv_slots = kv_slots
        self.sample = sample
        self.arch_id = arch_id or cfg.name
        self._clock = clock
        # sharded serving: place params on the mesh and run every step
        # inside its ShardingContext (trace-time constraint annotations)
        self.mesh = mesh
        self._ctx = shlib.ShardingContext(mesh) if mesh is not None else None
        if mesh is not None:
            params = jax.device_put(params,
                                    shlib.param_shardings(mesh, params))
        self.params = params
        # priority/EDF ordering; exact FIFO for requests without QoS
        self._queue = EDFQueue()
        self._zero_tok = np.zeros(
            (1, cfg.num_codebooks) if cfg.num_codebooks else (1,), np.int32)
        self._rng = jax.random.key(0)
        self._ewma_tok_s = 0.0         # measured seconds per decode round
        self._next_rid = 0
        self.peak_inflight = 0
        # overlapped stepping: uncollected device work from dispatch()
        self._pending: Optional[_Pending] = None
        self._round_t0 = 0.0           # decode-round enqueue time
        # fault-tolerance state (repro.faults)
        self.health = Health.HEALTHY
        self.fail_reason: Optional[str] = None
        self._stall_until = 0.0        # DEGRADED: frozen until this clock
        self._slow_every = 1           # DEGRADED: serve 1 step out of k
        self._step_seq = 0
        # prefix-cache accounting (0 forever on dense / cache-off engines)
        self.prefill_tokens_saved = 0
        self.prefix_hits = 0
        self.prefix_lookups = 0
        self.cow_forks = 0

        self.paged = paged_supported(cfg) if paged is None else bool(paged)
        if self.paged:
            self.page_size = page_size
            self.prefill_chunk = prefill_chunk
            if num_pages is None:
                # same KV token budget the dense pool would hold, plus the
                # reserved null page — the win is sharing, not more memory
                num_pages = 1 + kv_slots * cdiv(max_len, page_size)
            self.num_pages = num_pages
            self.max_lanes = max_lanes or 2 * kv_slots
            # fixed jit-stable block-table width: a max_len request plus
            # null padding for chunked-prefill overshoot writes
            self._row_width = (cdiv(max_len, page_size)
                               + cdiv(prefill_chunk, page_size) + 1)
            self._pool = PagePool(num_pages, page_size)
            self._lanes: List[Optional[_Lane]] = [None] * self.max_lanes
            self._paged_states = None   # built lazily on first admission
            self._paged_prefill = compiled.paged_prefill_step(
                cfg, num_pages, page_size, mesh=mesh)
            self._paged_decode = compiled.paged_decode_step(
                cfg, num_pages, page_size, sample, temperature, mesh=mesh)
            # automatic prefix caching (on by default for paged engines):
            # completed prompt blocks stay resident, refcount-shared with
            # later prompts that hash to the same token-block chain
            if prefix_cache is None or prefix_cache:
                self._prefix: Optional[PrefixCache] = PrefixCache(self._pool)
                self._page_copy = compiled.page_copy_step(
                    cfg, num_pages, page_size, mesh=mesh)
            else:
                self._prefix = None
                self._page_copy = None
        else:
            self._prefix = None
            self._prefill = compiled.prefill_step(cfg, max_len, mesh=mesh)
            self._slots: List[Optional[Request]] = [None] * kv_slots
            self._last_tok: List[Optional[np.ndarray]] = [None] * kv_slots
            self._pool_states = None   # (slots, ...) stacked per-slot caches
            self._pool_decode = compiled.pool_decode_step(
                cfg, kv_slots, sample, temperature, mesh=mesh)
            self._insert = compiled.pool_insert()

    def _sharded(self):
        """Context manager activating this engine's mesh rules (no-op for
        unsharded engines)."""
        if self._ctx is None:
            return contextlib.nullcontext()
        return shlib.use(self._ctx)

    # ------------------------------------------------------------------
    # continuous-batching core
    # ------------------------------------------------------------------
    def admit(self, req: Request) -> None:
        """Enqueue a request; it joins the decode batch when capacity
        (a dense slot, or a lane + enough free pages) opens up."""
        if self.health is Health.DOWN:
            raise RuntimeError(
                f"engine {getattr(self, 'engine_id', '?')} "
                f"({self.arch_id}) is DOWN"
                f"{f' ({self.fail_reason})' if self.fail_reason else ''}; "
                f"cannot admit request {req.rid}")
        req.t_enqueue = self._clock()
        req.engine_id = getattr(self, "engine_id", None)
        self._queue.append(req)

    def step(self) -> List[Request]:
        """One scheduling iteration; returns requests finished this step.

        Exactly ``dispatch()`` followed by ``collect()`` — the serial
        reference the overlapped cluster path is parity-tested against."""
        if not self.dispatch():
            return []
        return self.collect()

    def dispatch(self) -> bool:
        """Enqueue one step's device work WITHOUT a host sync.

        Runs admission, this round's prefill chunks, and the decode
        round, leaving the round's tokens as uncommitted device arrays;
        :meth:`collect` blocks on them and finalizes requests.  Returns
        False when the engine is gated off this step (DOWN, stalled, or
        slowdown-skipped) and no collect is pending.

        A DOWN engine is inert.  A DEGRADED engine is either stalled
        (frozen until ``_stall_until``, then self-healing — a transient
        straggler) or slowed (serving one step out of ``_slow_every``
        until an explicit :meth:`recover`)."""
        if self._pending is not None:
            raise RuntimeError(
                "dispatch() with an uncollected step in flight; call "
                "collect() first")
        if self.health is Health.DOWN:
            return False
        if self.health is Health.DEGRADED:
            now = self._clock()
            if now < self._stall_until:
                return False
            if self._stall_until and self._slow_every <= 1:
                self.recover()          # stall window elapsed
            else:
                self._stall_until = 0.0
                self._step_seq += 1
                if self._step_seq % self._slow_every:
                    return False
        if not self._queue and not self._inflight_requests():
            return False               # idle: nothing to enqueue
        self._pending = (self._dispatch_paged() if self.paged
                         else self._dispatch_dense())
        return True

    def collect(self) -> List[Request]:
        """Sync the dispatched round and finalize requests.

        One host round-trip per engine per step: the round's stacked
        decode tokens (plus any deferred prefill first-tokens, already
        computed by then).  Returns the requests that finished this step,
        prefill-completions first — the same order serial ``step()``
        produced.  A no-op (empty list) when nothing was dispatched."""
        if self._pending is None:
            return []
        pend, self._pending = self._pending, None
        finished: List[Request] = []

        # decode sync first: one blocking transfer for the whole round.
        # _note_round windows from the DISPATCH-time enqueue (t0) to
        # results-ready here, so the EWMA tok/s times only this engine's
        # device wait — not the other engines' host loops that ran
        # between its dispatch and its collect.
        tok_np = None
        if pend.decode is not None:
            tok_all, entries = pend.decode
            tok_np = np.asarray(tok_all)           # blocks until ready
            self._note_round(self._round_t0, len(entries))

        # deferred prefill first-tokens (ready by now: they were enqueued
        # before the decode round)
        for req, tok_dev, pos, fin in pend.prefill:
            req.tokens[pos] = np.asarray(tok_dev)
            req.t_prefill_end = self._clock()
            if fin:
                req.finish(req.t_prefill_end)
                finished.append(req)

        if pend.decode is not None:
            now = self._clock()
            for i, req, pos, fin in entries:
                tk = tok_np[i] if not self.paged else tok_np[i:i + 1]
                req.tokens[pos] = tk
                if fin:
                    req.finish(now)
                    finished.append(req)
                elif self.paged:
                    self._lanes[i].last_tok = tk
                else:
                    self._last_tok[i] = tk
        return finished

    @property
    def pending_collect(self) -> bool:
        """True between a dispatch() and its collect()."""
        return self._pending is not None

    def _dispatch_dense(self) -> _Pending:
        pend = _Pending()
        # admission: every joining request's prefill is ENQUEUED here but
        # its first-token sync is deferred to collect() — K admissions
        # cost one deferred round-trip, not K blocking ones
        free = [i for i, r in enumerate(self._slots) if r is None]
        while free and self._queue:
            req = self._queue.popleft()
            i = free.pop(0)
            req.t_prefill_start = self._clock()
            batch = {"tokens": req.prompt}
            if req.patches is not None:
                batch["patches"] = req.patches
            with self._sharded():
                logits, st = self._prefill(self.params, batch)
                tok = self._pick(logits)           # device; sync deferred
            pos = len(req.tokens)
            req.tokens.append(tok)
            if len(req.tokens) >= req.max_new_tokens:
                pend.prefill.append((req, tok, pos, True))
                free.insert(0, i)
                continue
            pend.prefill.append((req, tok, pos, False))
            self._ensure_pool(st)
            with self._sharded():
                self._pool_states = self._insert(self._pool_states, st,
                                                 jnp.int32(i))
            self._slots[i] = req
            self._last_tok[i] = tok
        self._note_inflight(sum(r is not None for r in self._slots))

        active = [i for i, r in enumerate(self._slots) if r is not None]
        if active:
            toks = jnp.stack([jnp.asarray(t if t is not None
                                          else self._zero_tok, jnp.int32)
                              for t in self._last_tok])
            keys = jax.random.split(self._next_key(), self.kv_slots)
            self._round_t0 = self._clock()
            with self._sharded():
                tok_all, self._pool_states = self._pool_decode(
                    self.params, toks[..., None], self._pool_states, keys)
            entries = []
            for i in active:
                req = self._slots[i]
                pos = len(req.tokens)
                req.tokens.append(tok_all[i])      # device slice, lazy
                self._last_tok[i] = tok_all[i]
                fin = len(req.tokens) >= req.max_new_tokens
                entries.append((i, req, pos, fin))
                if fin:
                    self._slots[i] = None
            pend.decode = (tok_all, entries)
        return pend

    # ------------------------------------------------------------------
    # paged step: page-gated admission, chunked prefill, decode round
    # ------------------------------------------------------------------
    def _dispatch_paged(self) -> _Pending:
        pend = _Pending()
        # 1. admission — head-of-line, gated on free pages (worst case
        # reserved up front) and a free lane.  The queue drains in
        # priority/EDF order (exact FIFO without QoS classes); no
        # skipping past the ordered head.
        free = [i for i, ln in enumerate(self._lanes) if ln is None]
        while free and self._queue:
            req = self._queue[0]
            plen = self._prompt_len(req)
            total = plen + req.max_new_tokens
            need = self._pool.pages_needed(total)
            if need > self._row_width - 1 - cdiv(self.prefill_chunk,
                                                 self.page_size):
                raise ValueError(
                    f"request needs {need} pages > per-request capacity "
                    f"(max_len={self.max_len})")
            # prefix match: reuse every cached page whose token-block
            # chain equals this prompt's.  The match is capped at
            # plen - 1 so at least one position is always prefilled
            # (the last-chunk logits produce the first token).
            m = None
            if self._prefix is not None:
                m = self._prefix.match(req.prompt, max_tokens=plen - 1)
                self.prefix_lookups += 1
            shared = m.pages if m is not None else []
            # retain matched pages FIRST so eviction below can never free
            # them, then make room for the private remainder by evicting
            # LRU cached leaves if needed
            if m is not None:
                self._prefix.acquire(m)
            need_new = need - len(shared)
            ok = (self._pool.num_free >= need_new
                  or (self._prefix is not None
                      and self._prefix.ensure_free(need_new)))
            if not ok:
                if m is not None:
                    self._prefix.release_match(m)
                break
            self._queue.popleft()
            i = free.pop(0)
            table = BlockTable(self._pool, total, shared=shared)
            matched = len(shared) * self.page_size
            if m is not None and m.cow_page is not None:
                # copy-on-write fork: the lane diverges mid-block, so it
                # gets a device-side copy of the partially-matching
                # cached page and re-prefills only from the divergence
                self._ensure_paged_states()
                dst = table.pages[len(shared)]
                with self._sharded():
                    self._paged_states = self._page_copy(
                        self._paged_states, jnp.int32(m.cow_page),
                        jnp.int32(dst))
                self._pool.release([m.cow_page])   # fork done: drop src
                matched += m.cow_tokens
                self.cow_forks += 1
            if matched:
                self.prefix_hits += 1
                self.prefill_tokens_saved += matched
                req.prefix_tokens = matched
            self._lanes[i] = _Lane(req=req, table=table, prompt_len=plen,
                                   chunk_pos=matched, length=matched)
        self._note_inflight(sum(ln is not None for ln in self._lanes))

        # 2. one prefill chunk per still-prefilling lane (device enqueue
        # only; last-chunk first-tokens sync in collect())
        self._ensure_paged_states()
        C = self.prefill_chunk
        for i, lane in enumerate(self._lanes):
            if lane is None or lane.decoding:
                continue
            req = lane.req
            if req.t_prefill_start is None:    # first chunk (chunk_pos may
                req.t_prefill_start = self._clock()  # start past 0 on a hit)
            c0 = lane.chunk_pos
            chunk = np.asarray(req.prompt[..., c0:c0 + C])
            pad = C - chunk.shape[-1]
            if pad:
                widths = [(0, 0)] * (chunk.ndim - 1) + [(0, pad)]
                chunk = np.pad(chunk, widths)
            row = jnp.asarray(lane.table.row(self._row_width), jnp.int32)
            with self._sharded():
                logits, self._paged_states = self._paged_prefill(
                    self.params,
                    {"tokens": jnp.asarray(chunk, jnp.int32),
                     "start": jnp.asarray(c0, jnp.int32),
                     "block_table": row},
                    self._paged_states)
            lane.chunk_pos = c0 + C
            lane.length = min(lane.chunk_pos, lane.prompt_len)
            if lane.decoding:                      # last chunk of prompt
                last = lane.prompt_len - 1 - c0
                with self._sharded():
                    tok = self._pick(logits[0, last][None])
                pos = len(req.tokens)
                req.tokens.append(tok)
                lane.last_tok = tok
                fin = len(req.tokens) >= req.max_new_tokens
                pend.prefill.append((req, tok, pos, fin))
                # the prompt's KV is complete: index every full prompt
                # block so later prompts with the same chain reuse it
                # (insert BEFORE any lane release so cached pages carry
                # their reference when the lane lets go)
                if self._prefix is not None:
                    self._prefix.insert(req.prompt, lane.table.pages)
                if fin:
                    self._free_lane(i)

        # 3. one decode round across the lanes that finished prefilling;
        # idle/prefilling lanes ride along masked (null table, length 0)
        active = [i for i, ln in enumerate(self._lanes)
                  if ln is not None and ln.decoding]
        if active:
            L, W = self.max_lanes, self._row_width
            rows = [self._zero_tok] * L
            tables = np.zeros((L, W), np.int32)
            lengths = np.zeros((L,), np.int32)
            for i in active:
                lane = self._lanes[i]
                rows[i] = lane.last_tok        # may be a device array
                tables[i] = lane.table.row(W)
                lengths[i] = lane.length
            toks = jnp.stack([jnp.asarray(r, jnp.int32) for r in rows])
            if self.cfg.num_codebooks:
                tok_in = jnp.transpose(toks, (0, 2, 1))  # (L,1,K)->(L,K,1)
            else:
                tok_in = toks                      # (L,1)
            self._round_t0 = self._clock()
            with self._sharded():
                _, tok_all, self._paged_states = self._paged_decode(
                    self.params,
                    {"tokens": tok_in,
                     "block_tables": jnp.asarray(tables, jnp.int32),
                     "lengths": jnp.asarray(lengths, jnp.int32)},
                    self._paged_states, self._next_key())
            entries = []
            for i in active:
                lane = self._lanes[i]
                req = lane.req
                tk = tok_all[i:i + 1]              # device slice, lazy
                pos = len(req.tokens)
                req.tokens.append(tk)
                lane.last_tok = tk
                lane.length += 1                   # decode wrote one KV
                fin = len(req.tokens) >= req.max_new_tokens
                entries.append((i, req, pos, fin))
                if fin:
                    self._free_lane(i)
            pend.decode = (tok_all, entries)
        return pend

    def run_to_completion(self, max_steps: int = 1_000_000) -> List[Request]:
        """Step until queue and slots drain; returns finished requests."""
        done = []
        for _ in range(max_steps):
            if not self.has_work:
                break
            done += self.step()
        return done

    def reset(self) -> None:
        """Drop queued/in-flight work and measurement state.

        Device pool contents need no zeroing — every KV position is
        written before it is read — but the rate EWMA and the request-id
        counter must restart or a reused engine reports the previous
        run's backlog estimate and non-monotonic request ids.  Paged
        engines release every lane and prefix-cache reference through the
        refcount path and ASSERT the pool returns to all-free — a reset
        is the one moment the refcount books must balance exactly, so a
        leak here is a bug, not a condition to paper over."""
        self._queue.clear()
        self._ewma_tok_s = 0.0
        self._next_rid = 0
        self.peak_inflight = 0
        self._pending = None
        self.health = Health.HEALTHY
        self.fail_reason = None
        self._stall_until = 0.0
        self._slow_every = 1
        self._step_seq = 0
        self.prefill_tokens_saved = 0
        self.prefix_hits = 0
        self.prefix_lookups = 0
        self.cow_forks = 0
        if self.paged:
            for i, lane in enumerate(self._lanes):
                if lane is not None:
                    lane.table.release()
            self._lanes = [None] * self.max_lanes
            if self._prefix is not None:
                self._prefix.clear()
            if self._pool.num_free != self.num_pages - 1:
                raise RuntimeError(
                    f"page pool leak on reset: {self._pool.num_free} free "
                    f"of {self.num_pages - 1} allocatable after releasing "
                    f"all lanes and the prefix cache")
            self._pool.reset()
        else:
            self._slots = [None] * self.kv_slots
            self._last_tok = [None] * self.kv_slots

    # ------------------------------------------------------------------
    # fault tolerance: health transitions (repro.faults)
    # ------------------------------------------------------------------
    def fail(self, reason: str = "injected crash") -> List[Request]:
        """Hard crash: mark DOWN, drain queued + in-flight requests and
        reclaim every KV page / dense slot they held.

        Returns the orphaned requests (queued first, then in-flight) so
        the cluster can re-offload them; their per-attempt state is NOT
        reset here — recovery policy belongs to the caller.  An
        uncollected dispatch is dropped: requests that finished inside it
        are orphaned too (their un-synced tokens are discarded on
        retry)."""
        orphans: List[Request] = list(self._queue)
        self._queue.clear()
        if self._pending is not None:
            pend, self._pending = self._pending, None
            orphans += [req for req, _, _, fin in pend.prefill if fin]
            if pend.decode is not None:
                orphans += [req for _, req, _, fin in pend.decode[1] if fin]
        if self.paged:
            for i, lane in enumerate(self._lanes):
                if lane is not None:
                    orphans.append(lane.req)
                    self._free_lane(i)
        else:
            for i, req in enumerate(self._slots):
                if req is not None:
                    orphans.append(req)
                    self._slots[i] = None
                    self._last_tok[i] = None
        self.health = Health.DOWN
        self.fail_reason = str(reason)
        return orphans

    def recover(self) -> None:
        """Return to HEALTHY after a crash, stall, or slowdown window."""
        self.health = Health.HEALTHY
        self.fail_reason = None
        self._stall_until = 0.0
        self._slow_every = 1

    def degrade(self, *, stall_s: float = 0.0, slow_every: int = 1,
                reason: str = "injected degradation") -> None:
        """Soft fault: freeze for ``stall_s`` seconds (transient
        straggler, self-healing) and/or serve only one step out of
        ``slow_every`` (sustained slowdown, until :meth:`recover`)."""
        if self.health is Health.DOWN:
            raise RuntimeError("cannot degrade a DOWN engine; recover it "
                               "first")
        self.health = Health.DEGRADED
        self.fail_reason = str(reason)
        if stall_s > 0:
            self._stall_until = self._clock() + stall_s
        self._slow_every = max(int(slow_every), 1)

    @property
    def available(self) -> bool:
        """Placement-eligible (DEGRADED still serves, DOWN does not)."""
        return self.health is not Health.DOWN

    @property
    def availability(self) -> float:
        """Observation feature: 1 healthy, 0.5 degraded, 0 down."""
        return AVAILABILITY[self.health]

    @property
    def kv_leak(self) -> int:
        """Outstanding KV reservations (page references, or busy dense
        slots), net of the prefix cache's deliberate residency.

        The prefix cache holds exactly ONE pool reference per entry, so
        ``total_refs - cache.size`` counts every reference owed to live
        lanes.  0 whenever the engine is idle — the crash-recovery
        invariant the chaos tests assert, now refcount-exact: a crash
        mid-prefill on a SHARED prefix must drop only the crashed lane's
        references, leaving cached pages resident and every refcount
        right."""
        if self.paged:
            held = self._prefix.size if self._prefix is not None else 0
            return self._pool.total_refs - held
        return sum(r is not None for r in self._slots)

    def shed(self, pred) -> List[Request]:
        """Remove queued (not yet running) requests matching ``pred`` —
        the cluster watchdog's shedding hook."""
        return self._queue.drain(pred)

    # ------------------------------------------------------------------
    # backlog signals (the scheduler's q_b / Eqn-3 observation)
    # ------------------------------------------------------------------
    def _inflight_requests(self) -> List[Request]:
        if self.paged:
            return [ln.req for ln in self._lanes if ln is not None]
        return [r for r in self._slots if r is not None]

    @property
    def has_work(self) -> bool:
        return (bool(self._queue) or bool(self._inflight_requests())
                or self._pending is not None)

    @property
    def pending_tokens(self) -> int:
        """Tokens still to generate across queued + in-flight requests."""
        n = sum(r.max_new_tokens for r in self._queue)
        n += sum(r.max_new_tokens - len(r.tokens)
                 for r in self._inflight_requests())
        return n

    @property
    def pending_seconds(self) -> float:
        """Measured backlog estimate: pending tokens x EWMA token time."""
        return self.pending_tokens * self._ewma_tok_s

    # ------------------------------------------------------------------
    # prefix-cache signals (the scheduler's affinity feature)
    # ------------------------------------------------------------------
    def expected_prefix_tokens(self, req: Request) -> int:
        """Prompt tokens this engine could skip for ``req`` RIGHT NOW — a
        pure peek against the prefix index (no reference taken, no LRU
        bump).  0 for dense / cache-off engines.  This is the per-engine
        observation feature the prefix-affinity scheduler routes on: the
        paper's thesis is to send work where it finishes fastest, and a
        matched prefix is compute already done."""
        if not self.paged or self._prefix is None:
            return 0
        m = self._prefix.match(req.prompt,
                               max_tokens=self._prompt_len(req) - 1)
        return m.tokens

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of admissions that reused at least one cached page."""
        if not self.prefix_lookups:
            return 0.0
        return self.prefix_hits / self.prefix_lookups

    @property
    def prefix_cached_pages(self) -> int:
        return self._prefix.size if (self.paged and self._prefix is not None
                                     ) else 0

    @property
    def prefix_evictions(self) -> int:
        return (self._prefix.evictions
                if (self.paged and self._prefix is not None) else 0)

    @property
    def est_token_seconds(self) -> float:
        """Seconds per decode token: measured EWMA once the engine has run
        a round, else a FLOPs-based cold prior (the paper's rho_n / f_b)."""
        if self._ewma_tok_s > 0:
            return self._ewma_tok_s
        return cold_token_seconds(self.cfg)

    @property
    def capability(self) -> EngineCapability:
        """Snapshot of this engine as an edge-server capability descriptor:
        its live f_b' (measured tok/s) and per-step cost rho_n."""
        active = self.cfg.active_param_count()
        return EngineCapability(
            arch=self.arch_id,
            model_name=self.cfg.name,
            num_layers=self.cfg.num_layers,
            d_model=self.cfg.d_model,
            active_params=active,
            rho_gcycles=2.0 * active / 1e9,
            tok_s=1.0 / self.est_token_seconds,
            measured=self._ewma_tok_s > 0,
            paged=self.paged,
            prefix_hit_rate=self.prefix_hit_rate,
            prefix_cached_tokens=self.prefix_cached_pages * (
                self.page_size if self.paged else 0))

    # ------------------------------------------------------------------
    # blocking compatibility API
    # ------------------------------------------------------------------
    def generate(self, prompts: jnp.ndarray, num_tokens: int,
                 rng: Optional[jax.Array] = None,
                 patches: Optional[jnp.ndarray] = None) -> RequestResult:
        """prompts (B, S) [or (B, K, S) audio] -> (B,)-stacked tokens per
        generated step, plus measured timing (admit all, drain)."""
        if rng is not None:
            self._rng = rng
        reqs = []
        for b in range(prompts.shape[0]):
            reqs.append(Request(
                rid=self._next_rid, prompt=prompts[b:b + 1],
                max_new_tokens=max(num_tokens, 1),
                patches=None if patches is None else patches[b:b + 1]))
            self._next_rid += 1
            self.admit(reqs[-1])
        self.run_to_completion()
        toks = [np.concatenate([r.tokens[s] for r in reqs], axis=0)
                for s in range(max(num_tokens, 1))]
        t_dec0 = max(r.t_prefill_end for r in reqs)
        t_end = max(r.t_finish for r in reqs)
        return RequestResult(
            tokens=toks,
            prefill_s=sum(r.prefill_s for r in reqs),
            decode_s=max(t_end - t_dec0, 0.0),
            queue_s=max(max(r.queue_s for r in reqs), 0.0))

    # ------------------------------------------------------------------
    # internals
    # ------------------------------------------------------------------
    def _next_key(self):
        self._rng, k = jax.random.split(self._rng)
        return k

    def _pick(self, logits):
        if self.sample:
            return jax.random.categorical(self._next_key(), logits, axis=-1
                                          ).astype(jnp.int32)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    @staticmethod
    def _prompt_len(req: Request) -> int:
        return int(req.prompt.shape[-1])

    def _note_round(self, t0: float, active: int) -> None:
        # a round advances every active lane one token, so the per-token
        # drain rate is round time / active lanes.  t0 is stamped at
        # DISPATCH (device enqueue) and the sync lands in collect(), so
        # this windows exactly one engine's enqueue-to-ready device wait;
        # a whole-cluster-step window would absorb the other engines'
        # compute under overlapped stepping and corrupt the capability
        # descriptor's f_b' (and with it the deadline-aware scheduler's
        # affinity features).
        dt = (self._clock() - t0) / active
        self._ewma_tok_s = (0.7 * self._ewma_tok_s + 0.3 * dt
                            if self._ewma_tok_s else dt)

    def _note_inflight(self, n: int) -> None:
        self.peak_inflight = max(self.peak_inflight, n)

    def _free_lane(self, i: int) -> None:
        self._lanes[i].table.release()
        self._lanes[i] = None

    def _ensure_paged_states(self) -> None:
        if self._paged_states is None:
            from repro.models.transformer import init_paged_states
            states = init_paged_states(self.cfg, self.num_pages,
                                       self.page_size)
            self._paged_states = self._place_states(states)

    def _ensure_pool(self, st):
        """Lazily build the slot pool from the structure of the first
        prefill's cache (covers every arch family: attention ring
        buffers, quantised caches, recurrent states)."""
        if self._pool_states is not None:
            return
        slots = self.kv_slots
        pool = jax.tree_util.tree_map(
            lambda leaf: jnp.zeros((slots,) + leaf.shape, leaf.dtype), st)
        self._pool_states = self._place_states(pool)

    def _place_states(self, states):
        """Shard KV / recurrent state onto the engine's mesh (identity
        when unsharded); divisibility-guarded per leaf."""
        if self.mesh is None:
            return states
        shardings = shlib.state_shardings(self.mesh, states)
        return jax.device_put(states, shardings)


def serve_batch(engines: List[ServeEngine], assignments: List[int],
                prompts: List[jnp.ndarray], num_tokens: int
                ) -> List[RequestResult]:
    """Route each prompt to its assigned engine, serve them concurrently
    (continuous batching within each engine), return per-request results.

    Overlapped stepping: every busy engine's round is DISPATCHED before
    any engine's results are collected, so E engines' decode rounds run
    concurrently on device instead of paying E serial host syncs."""
    reqs = []
    for i, pr in enumerate(prompts):
        # prompts arrive unbatched — (S,) text or (K, S) audio — and gain
        # the leading batch dim here (matching the original serve_batch)
        req = Request(rid=i, prompt=pr[None],
                      max_new_tokens=max(num_tokens, 1))
        reqs.append(req)
        engines[assignments[i]].admit(req)
    while any(e.has_work for e in engines):
        busy = [e for e in engines if e.has_work]
        for e in busy:          # an idle engine's step is not free: it
            e.dispatch()        # still pays host-side bookkeeping
        for e in busy:
            e.collect()
    return [RequestResult(tokens=r.tokens, prefill_s=r.prefill_s,
                          decode_s=r.decode_s, queue_s=r.queue_s)
            for r in reqs]
