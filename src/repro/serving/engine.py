"""Continuous-batching serving engine: the per-ES "DEdgeAI worker".

One engine wraps one model replica with a FIXED pool of KV slots.
Requests are ``admit()``-ed into a queue; each ``step()``

  1. refills free slots from the queue — one batch-1 prefill per joining
     request, whose cache is written into the slot pool, and
  2. runs ONE batched decode round across all occupied slots (a jitted
     ``vmap`` over the per-slot caches, so every slot keeps its own
     ``pos`` counter and requests can join/leave mid-flight), freeing the
     slots of requests that hit their token budget.

Per-request latency is MEASURED, not modelled: the Request lifecycle
timestamps (queue / prefill / decode) decompose the serving-side terms of
the paper's Eqn (2) exactly, replacing the old ``_busy_until`` wall-clock
queue hack.  The edge-level scheduler (``repro.cluster``) decides WHICH
engine serves a request; the engine reports its backlog via
``pending_tokens`` / ``pending_seconds`` (the q_b signal of Eqn 3).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster.request import Request
from repro.train.steps import make_decode_step, make_prefill_step


@dataclasses.dataclass
class RequestResult:
    """Batch-level result of the blocking :meth:`ServeEngine.generate`.

    For B == 1 the three phases decompose the request's wall time
    exactly.  For a batch they are aggregates — worst per-request queue
    wait (slot contention when B > kv_slots), summed prefill compute,
    and the shared decode span; per-request timestamps are available
    through the ``admit()``/``step()`` API instead."""

    tokens: list
    prefill_s: float
    decode_s: float
    queue_s: float

    @property
    def total_s(self) -> float:
        return self.prefill_s + self.decode_s + self.queue_s


class ServeEngine:
    """Continuous-batching engine for one model replica."""

    def __init__(self, cfg, params, *, max_len: int = 256,
                 kv_slots: int = 4, sample: bool = False,
                 temperature: float = 1.0,
                 clock: Callable[[], float] = time.monotonic):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.kv_slots = kv_slots
        self.sample = sample
        self._clock = clock
        self._prefill = jax.jit(make_prefill_step(cfg, max_len=max_len))
        self._decode1 = make_decode_step(cfg, sample=sample,
                                         temperature=temperature)
        self._queue: collections.deque = collections.deque()
        self._slots: List[Optional[Request]] = [None] * kv_slots
        self._last_tok: List[Optional[np.ndarray]] = [None] * kv_slots
        self._pool_states = None       # (slots, ...) stacked per-slot caches
        self._pool_decode = None
        self._insert = None
        self._zero_tok = np.zeros(
            (1, cfg.num_codebooks) if cfg.num_codebooks else (1,), np.int32)
        self._rng = jax.random.key(0)
        self._ewma_tok_s = 0.0         # measured seconds per decode round
        self._next_rid = 0

    # ------------------------------------------------------------------
    # continuous-batching core
    # ------------------------------------------------------------------
    def admit(self, req: Request) -> None:
        """Enqueue a request; it joins the decode batch when a slot frees."""
        req.t_enqueue = self._clock()
        req.engine_id = getattr(self, "engine_id", None)
        self._queue.append(req)

    def step(self) -> List[Request]:
        """One scheduling iteration; returns requests finished this step."""
        finished = []
        free = [i for i, r in enumerate(self._slots) if r is None]
        while free and self._queue:
            req = self._queue.popleft()
            i = free.pop(0)
            req.t_prefill_start = self._clock()
            batch = {"tokens": req.prompt}
            if req.patches is not None:
                batch["patches"] = req.patches
            logits, st = self._prefill(self.params, batch)
            tok = np.asarray(self._pick(logits))
            req.t_prefill_end = self._clock()
            req.tokens.append(tok)
            if len(req.tokens) >= req.max_new_tokens:
                req.t_finish = req.t_prefill_end
                finished.append(req)
                free.insert(0, i)
                continue
            self._ensure_pool(st)
            self._pool_states = self._insert(self._pool_states, st,
                                             jnp.int32(i))
            self._slots[i] = req
            self._last_tok[i] = tok

        active = [i for i, r in enumerate(self._slots) if r is not None]
        if active:
            toks = np.stack([t if t is not None else self._zero_tok
                             for t in self._last_tok])
            keys = jax.random.split(self._next_key(), self.kv_slots)
            t0 = self._clock()
            tok_all, self._pool_states = self._pool_decode(
                self.params, jnp.asarray(toks[..., None], jnp.int32),
                self._pool_states, keys)
            tok_all = np.asarray(tok_all)          # blocks until ready
            # a round advances every occupied slot one token, so the
            # per-token drain rate is round time / active lanes
            dt = (self._clock() - t0) / len(active)
            self._ewma_tok_s = (0.7 * self._ewma_tok_s + 0.3 * dt
                                if self._ewma_tok_s else dt)
            now = self._clock()
            for i in active:
                req = self._slots[i]
                tk = tok_all[i]
                req.tokens.append(tk)
                self._last_tok[i] = tk
                if len(req.tokens) >= req.max_new_tokens:
                    req.t_finish = now
                    finished.append(req)
                    self._slots[i] = None
        return finished

    def run_to_completion(self, max_steps: int = 1_000_000) -> List[Request]:
        """Step until queue and slots drain; returns finished requests."""
        done = []
        for _ in range(max_steps):
            if not self.has_work:
                break
            done += self.step()
        return done

    def reset(self) -> None:
        """Drop queued/in-flight work (pool caches are overwritten on use)."""
        self._queue.clear()
        self._slots = [None] * self.kv_slots
        self._last_tok = [None] * self.kv_slots

    # ------------------------------------------------------------------
    # backlog signals (the scheduler's q_b / Eqn-3 observation)
    # ------------------------------------------------------------------
    @property
    def has_work(self) -> bool:
        return bool(self._queue) or any(r is not None for r in self._slots)

    @property
    def pending_tokens(self) -> int:
        """Tokens still to generate across queued + in-flight requests."""
        n = sum(r.max_new_tokens for r in self._queue)
        n += sum(r.max_new_tokens - len(r.tokens)
                 for r in self._slots if r is not None)
        return n

    @property
    def pending_seconds(self) -> float:
        """Measured backlog estimate: pending tokens x EWMA token time."""
        return self.pending_tokens * self._ewma_tok_s

    # ------------------------------------------------------------------
    # blocking compatibility API
    # ------------------------------------------------------------------
    def generate(self, prompts: jnp.ndarray, num_tokens: int,
                 rng: Optional[jax.Array] = None,
                 patches: Optional[jnp.ndarray] = None) -> RequestResult:
        """prompts (B, S) [or (B, K, S) audio] -> (B,)-stacked tokens per
        generated step, plus measured timing (admit all, drain)."""
        if rng is not None:
            self._rng = rng
        reqs = []
        for b in range(prompts.shape[0]):
            reqs.append(Request(
                rid=self._next_rid, prompt=prompts[b:b + 1],
                max_new_tokens=max(num_tokens, 1),
                patches=None if patches is None else patches[b:b + 1]))
            self._next_rid += 1
            self.admit(reqs[-1])
        self.run_to_completion()
        toks = [np.concatenate([r.tokens[s] for r in reqs], axis=0)
                for s in range(max(num_tokens, 1))]
        t_dec0 = max(r.t_prefill_end for r in reqs)
        t_end = max(r.t_finish for r in reqs)
        return RequestResult(
            tokens=toks,
            prefill_s=sum(r.prefill_s for r in reqs),
            decode_s=max(t_end - t_dec0, 0.0),
            queue_s=max(max(r.queue_s for r in reqs), 0.0))

    # ------------------------------------------------------------------
    # internals
    # ------------------------------------------------------------------
    def _next_key(self):
        self._rng, k = jax.random.split(self._rng)
        return k

    def _pick(self, logits):
        if self.sample:
            return jax.random.categorical(self._next_key(), logits, axis=-1
                                          ).astype(jnp.int32)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def _ensure_pool(self, st):
        """Lazily build the slot pool + jitted batched decode from the
        structure of the first prefill's cache (covers every arch family:
        attention ring buffers, quantised caches, recurrent states)."""
        if self._pool_states is not None:
            return
        slots = self.kv_slots
        self._pool_states = jax.tree_util.tree_map(
            lambda leaf: jnp.zeros((slots,) + leaf.shape, leaf.dtype), st)
        self._insert = jax.jit(lambda pool, s, i: jax.tree_util.tree_map(
            lambda p_, s_: p_.at[i].set(s_), pool, s))
        dec, sample = self._decode1, self.sample

        def pool_step(params, toks, states, keys):
            def one(tk, st_, k):
                if sample:
                    _, tok, ns = dec(params, {"tokens": tk}, st_, rng=k)
                else:
                    _, tok, ns = dec(params, {"tokens": tk}, st_)
                return tok, ns

            return jax.vmap(one)(toks, states, keys)

        self._pool_decode = jax.jit(pool_step)


def serve_batch(engines: List[ServeEngine], assignments: List[int],
                prompts: List[jnp.ndarray], num_tokens: int
                ) -> List[RequestResult]:
    """Route each prompt to its assigned engine, serve them concurrently
    (continuous batching within each engine), return per-request results."""
    reqs = []
    for i, pr in enumerate(prompts):
        # prompts arrive unbatched — (S,) text or (K, S) audio — and gain
        # the leading batch dim here (matching the original serve_batch)
        req = Request(rid=i, prompt=pr[None],
                      max_new_tokens=max(num_tokens, 1))
        reqs.append(req)
        engines[assignments[i]].admit(req)
    while any(e.has_work for e in engines):
        for e in engines:
            e.step()
    return [RequestResult(tokens=r.tokens, prefill_s=r.prefill_s,
                          decode_s=r.decode_s, queue_s=r.queue_s)
            for r in reqs]
