"""Shared engine-cluster construction: one factory + warmup for the
launcher, the examples, and the benchmarks (so they all measure
identically configured clusters).

Fleet construction is compile-cheap: engines fetch their jitted
prefill/decode steps from the shared compiled-step cache
(``repro.serving.compiled``), so N same-config replicas cost ONE
compile, not N — ``build_engines``/``build_fleet`` at fleet scale go
from O(E) compiles to O(distinct archs x depths).

Sharded big-model engines: :func:`build_sharded_engine` places a single
large config (``mixtral-8x22b``, ``dbrx-132b``) across a mesh — params
via ``launch.sharding.param_shardings``, KV/recurrent state via
``state_pspecs`` — using the 1-device smoke mesh on CPU CI and
``make_production_mesh()`` on real device slices.  ``build_engines`` /
``build_fleet`` accept the same ``mesh=`` to shard every replica.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax

from repro.configs import get_config, reduced
from repro.launch.mesh import make_smoke_mesh
from repro.models.transformer import init_params
from repro.serving.engine import ServeEngine


def default_depths(n_edge: int) -> List[int]:
    """Heterogeneous layer depths — the cluster's speed diversity."""
    return [2 + 2 * (i % 2) for i in range(n_edge)]


def build_engines(arch: str, n_edge: int, max_len: int, *,
                  kv_slots: int = 4, sample: bool = False,
                  depths: Optional[Sequence[int]] = None,
                  seed0: int = 0, paged: Optional[bool] = None,
                  page_size: int = 16, max_lanes: Optional[int] = None,
                  prefill_chunk: int = 64,
                  prefix_cache: Optional[bool] = None,
                  mesh=None) -> List[ServeEngine]:
    """n_edge reduced-config replicas of ``arch`` with per-engine depth.

    ``paged=None`` auto-selects the shared page pool on all-attention
    configs and the dense slot pool elsewhere; the remaining paged knobs
    are ignored by dense engines.  Same-depth replicas share compiled
    steps through the module cache."""
    depths = list(depths) if depths is not None else default_depths(n_edge)
    engines = []
    for i in range(n_edge):
        cfg = dataclasses.replace(reduced(get_config(arch)),
                                  num_layers=depths[i])
        params = init_params(jax.random.key(seed0 + i), cfg)
        engines.append(ServeEngine(cfg, params, max_len=max_len,
                                   kv_slots=kv_slots, sample=sample,
                                   paged=paged, page_size=page_size,
                                   max_lanes=max_lanes,
                                   prefill_chunk=prefill_chunk,
                                   prefix_cache=prefix_cache,
                                   arch_id=arch, mesh=mesh))
    return engines


def build_fleet(archs: Sequence[str], max_len: int, *,
                kv_slots: int = 4, sample: bool = False,
                depths: Optional[Sequence[int]] = None,
                seed0: int = 0, paged: Optional[bool] = None,
                page_size: int = 16, max_lanes: Optional[int] = None,
                prefill_chunk: int = 64,
                prefix_cache: Optional[bool] = None,
                mesh=None) -> List[ServeEngine]:
    """Heterogeneous fleet: one engine PER ENTRY of ``archs``.

    Unlike :func:`build_engines` (n replicas of one arch), each engine
    here hosts a different reduced model-zoo config — mixed arch
    families mean mixed KV backends (paged attention pools next to
    dense xLSTM/RG slot pools) behind the same cluster interface.  The
    engine's ``arch_id`` tags it for request ``model_pref`` affinity.
    Repeated (arch, depth) entries share one compiled step via the
    module-level cache."""
    archs = list(archs)
    depths = (list(depths) if depths is not None
              else default_depths(len(archs)))
    engines = []
    for i, arch in enumerate(archs):
        cfg = dataclasses.replace(reduced(get_config(arch)),
                                  num_layers=depths[i])
        params = init_params(jax.random.key(seed0 + i), cfg)
        engines.append(ServeEngine(cfg, params, max_len=max_len,
                                   kv_slots=kv_slots, sample=sample,
                                   paged=paged, page_size=page_size,
                                   max_lanes=max_lanes,
                                   prefill_chunk=prefill_chunk,
                                   prefix_cache=prefix_cache,
                                   arch_id=arch, mesh=mesh))
    return engines


def build_sharded_engine(arch: str, max_len: int, *, mesh=None,
                         full_scale: bool = False, num_layers: int = 2,
                         kv_slots: int = 4, sample: bool = False,
                         paged: Optional[bool] = None, page_size: int = 16,
                         max_lanes: Optional[int] = None,
                         prefill_chunk: int = 64,
                         prefix_cache: Optional[bool] = None,
                         seed: int = 0) -> ServeEngine:
    """One BIG-model engine with params + KV placed across a mesh.

    This is the serving entry point for the configs a single chip cannot
    hold (``mixtral-8x22b``, ``dbrx-132b``): parameters shard via the
    path-based ``param_shardings`` rules (tensor-parallel 'model' +
    FSDP 'data'), the KV page pool / dense slot pool via ``state_pspecs``
    — divisibility-guarded, so indivisible dims replicate instead of
    erroring — and every prefill/decode step runs inside the mesh's
    ShardingContext.

    ``mesh=None`` uses the 1-device smoke mesh (CPU CI exercises the
    exact placement code paths); pass ``make_production_mesh()`` on a
    real slice.  ``full_scale=False`` serves the reduced config at the
    true layer pattern family (CI-sized); ``full_scale=True`` keeps the
    paper-scale dimensions (requires the memory of a real mesh)."""
    if mesh is None:
        mesh = make_smoke_mesh()
    cfg = get_config(arch)
    if not full_scale:
        cfg = dataclasses.replace(reduced(cfg), num_layers=num_layers)
    params = init_params(jax.random.key(seed), cfg)
    return ServeEngine(cfg, params, max_len=max_len, kv_slots=kv_slots,
                       sample=sample, paged=paged, page_size=page_size,
                       max_lanes=max_lanes, prefill_chunk=prefill_chunk,
                       prefix_cache=prefix_cache, arch_id=arch, mesh=mesh)


def warmup(engines: Sequence[ServeEngine], prompt_len: int,
           gen_tokens: int = 2) -> None:
    """Compile prefill + pool decode before timed serving (handles the
    audio codebook and vision patch frontends).  Thanks to the shared
    compiled-step cache, warming one engine per distinct (config, mesh)
    warms its whole replica group."""
    for e in engines:
        cfg = e.cfg
        shape = ((1, cfg.num_codebooks, prompt_len) if cfg.num_codebooks
                 else (1, prompt_len))
        warm = jax.random.randint(jax.random.key(1), shape, 0,
                                  cfg.vocab_size)
        patches = None
        if cfg.vision_patches:
            patches = jax.random.normal(
                jax.random.key(2), (1, cfg.vision_patches, cfg.vision_dim))
        e.generate(warm, max(gen_tokens, 2), patches=patches)
        e.reset()
