"""Shared compiled-step cache: one jitted executable per (config, shape).

Every ``ServeEngine`` used to wrap its own ``jax.jit(make_*_step(cfg))``,
so an E-engine fleet paid E identical compiles (and ``build_fleet`` at
scale re-jitted the same reduced config once per replica).  The cache
here is module level and keyed on the full *step identity* —

    (kind, cfg, max_len / kv_slots / num_pages+page_size, sample,
     temperature, mesh)

— so the N same-arch engines in a fleet share ONE jitted callable, and
jax's own executable cache then shares the compiled program across them:
fleet construction goes from O(E) compiles to O(distinct archs).  The
``cfg`` key is the frozen ``ModelConfig`` dataclass itself (hashable by
value), so two engines share a wrapper exactly when their configs are
equal; ``mesh`` participates because sharding constraints are baked in
at trace time.

Decode-round states are DONATED (``donate_argnums``): the per-round KV
pool / recurrent-state output reuses the input buffer in place instead
of allocating a fresh multi-MB copy every token — the engine always
rebinds its state reference to the step's output, so the invalidated
input is never read again.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax

from repro.train.steps import (make_decode_step, make_paged_decode_step,
                               make_paged_prefill_step, make_prefill_step)

_CACHE: Dict[Tuple, Callable] = {}
_STATS = {"hits": 0, "misses": 0}


def _get(key: Tuple, build: Callable[[], Callable]) -> Callable:
    fn = _CACHE.get(key)
    if fn is None:
        _STATS["misses"] += 1
        fn = _CACHE[key] = build()
    else:
        _STATS["hits"] += 1
    return fn


def cache_info() -> Dict[str, Any]:
    """Snapshot for tests / diagnostics: entry keys + hit counters."""
    return {"size": len(_CACHE), "keys": list(_CACHE), **_STATS}


def clear_cache() -> None:
    _CACHE.clear()
    _STATS["hits"] = _STATS["misses"] = 0


# ---------------------------------------------------------------------------
# dense slot-pool steps
# ---------------------------------------------------------------------------


def prefill_step(cfg, max_len: int, mesh=None) -> Callable:
    """Jitted batch-1 prefill (returns logits + fresh per-request state)."""
    return _get(("prefill", cfg, max_len, mesh),
                lambda: jax.jit(make_prefill_step(cfg, max_len=max_len)))


def pool_insert() -> Callable:
    """Jitted slot insert ``pool.at[i].set(state)`` (structure-agnostic:
    jax retraces per state pytree, the wrapper is shared by everyone)."""
    return _get(("insert",), lambda: jax.jit(
        lambda pool, s, i: jax.tree_util.tree_map(
            lambda p_, s_: p_.at[i].set(s_), pool, s)))


def pool_decode_step(cfg, kv_slots: int, sample: bool, temperature: float,
                     mesh=None) -> Callable:
    """Jitted one-token decode round vmapped over the dense slot pool.

    signature: (params, toks (slots, 1, ...), pool_states, keys) ->
    (tokens, new_pool_states); ``pool_states`` is donated (the round
    rewrites the pool in place instead of copying it)."""
    def build():
        dec = make_decode_step(cfg, sample=sample, temperature=temperature)

        def pool_step(params, toks, states, keys):
            def one(p, tk, st_, k):
                if sample:
                    _, tok, ns = dec(p, {"tokens": tk}, st_, rng=k)
                else:
                    _, tok, ns = dec(p, {"tokens": tk}, st_)
                return tok, ns

            return jax.vmap(one, in_axes=(None, 0, 0, 0))(
                params, toks, states, keys)

        return jax.jit(pool_step, donate_argnums=2)

    return _get(("pool_decode", cfg, kv_slots, sample, temperature, mesh),
                build)


# ---------------------------------------------------------------------------
# paged page-pool steps
# ---------------------------------------------------------------------------


def paged_prefill_step(cfg, num_pages: int, page_size: int,
                       mesh=None) -> Callable:
    """Jitted one-chunk paged prefill; the shared page pools are donated
    (each chunk rewrites a few pages of a large pool — copying the whole
    pool per chunk would dwarf the chunk's own compute)."""
    return _get(("paged_prefill", cfg, num_pages, page_size, mesh),
                lambda: jax.jit(make_paged_prefill_step(cfg),
                                donate_argnums=2))


def paged_decode_step(cfg, num_pages: int, page_size: int, sample: bool,
                      temperature: float, mesh=None) -> Callable:
    """Jitted paged decode round (donated page pools, same rationale)."""
    return _get(
        ("paged_decode", cfg, num_pages, page_size, sample, temperature,
         mesh),
        lambda: jax.jit(make_paged_decode_step(cfg, sample=sample,
                                               temperature=temperature),
                        donate_argnums=2))


def page_copy_step(cfg, num_pages: int, page_size: int, mesh=None) -> Callable:
    """Jitted copy-on-write page fork: every per-layer K/V pool copies
    physical page ``src`` onto page ``dst`` in place (donated states, and
    src/dst are traced scalars so one compile covers every fork).  Used
    when a lane's prompt diverges MID-block from a cached prefix: the
    matched head of the cached page is duplicated so the lane can
    overwrite its private tail without corrupting the shared original."""
    def build():
        def copy(states, src, dst):
            return jax.tree_util.tree_map(
                lambda a: a.at[dst].set(a[src]), states)

        return jax.jit(copy, donate_argnums=0)

    return _get(("page_copy", cfg, num_pages, page_size, mesh), build)
