"""Online distributed training harness (Algorithm 1).

One jitted function rolls an entire episode: scan over the T time slots,
inner scan over the N_max task slots.  At each (t, n) the B per-ES agents
decide in parallel (vmap over stacked agent states — the paper's
"for all BS b in parallel"); queues couple them globally via Eqn (4).

Transitions are emitted one step late (s_next is observed at the next
(t, n)), stored in each agent's pool, and — once |R| > 300 — every step
triggers one SAC update per agent (Algorithm 1 lines 15-18).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import agents as ag
from repro.core import env as envlib

METHODS = ("lad-ts", "d2sac-ts", "sac-ts", "dqn-ts", "opt-ts", "random-ts",
           "local-ts")
LEARNED = ("lad-ts", "d2sac-ts", "sac-ts", "dqn-ts")


def make_agent_fns(method: str, cfg: ag.AgentConfig):
    """(init, act, update, add_replay, latent) for a method, all vmappable.

    act(state, s, n, key) -> (action, x_used, new_state)
    """
    if method in ("lad-ts", "d2sac-ts"):
        dcfg = dataclasses.replace(cfg.diffusion,
                                   latent_init=(method == "lad-ts"))
        cfg = dataclasses.replace(cfg, diffusion=dcfg)

        def init(key, sd, adim, nmax):
            return ag.ladts_init(key, cfg, sd, adim, nmax)

        def act(state, s, n, key, greedy=False):
            x_used = (state.X[n] if cfg.diffusion.latent_init
                      else jax.random.normal(jax.random.fold_in(key, 7),
                                             state.X[0].shape))
            a, state = ag.ladts_act(state, cfg, s, n, key, greedy=greedy)
            return a, x_used, state

        def update(state, key):
            return ag.ladts_update(state, cfg, key)

        def latent(state, n):
            return state.X[n]

    elif method == "sac-ts":
        def init(key, sd, adim, nmax):
            return ag.sac_init(key, cfg, sd, adim, nmax)

        def act(state, s, n, key, greedy=False):
            a = ag.sac_act(state, cfg, s, key, greedy=greedy)
            return a, jnp.zeros((state.c1[-1]["b"].shape[0],)), state

        def update(state, key):
            return ag.sac_update(state, cfg, key)

        def latent(state, n):
            return jnp.zeros((state.c1[-1]["b"].shape[0],))

    elif method == "dqn-ts":
        def init(key, sd, adim, nmax):
            return ag.dqn_init(key, cfg, sd, adim, nmax)

        def act(state, s, n, key, greedy=False):
            a = ag.dqn_act(state, cfg, s, key, greedy=greedy)
            return a, jnp.zeros((state.q[-1]["b"].shape[0],)), state

        def update(state, key):
            return ag.dqn_update(state, cfg, key)

        def latent(state, n):
            return jnp.zeros((state.q[-1]["b"].shape[0],))

    else:
        raise ValueError(method)

    def add_replay(state, item, valid):
        return state._replace(replay=ag.replay_add(state.replay, item,
                                                   valid))

    return init, act, update, add_replay, latent


class Pending(NamedTuple):
    """Previous step's half-built transitions (B, ...)."""
    s: jnp.ndarray
    x: jnp.ndarray
    a: jnp.ndarray
    r: jnp.ndarray
    valid: jnp.ndarray


def heuristic_actions(method: str, p: envlib.EnvParams, ep, qs, t, n, key):
    """Non-learned schedulers (B,) actions."""
    B = p.num_bs
    if method == "random-ts":
        return jax.random.randint(key, (B,), 0, B)
    if method == "local-ts":
        return jnp.arange(B)
    # opt-ts: enumerate all B placements for each task; pick min T_serv.
    # (B_src, B_tgt) delay matrix using the true capacities and queues.
    d = ep.d[t, n][:, None]
    z = ep.z[t, n][:, None]
    rho = ep.rho[t, n][:, None]
    d_out = ep.d_out[t, n][:, None]
    v_up = ep.v_up[t, n][:, None]
    v_down = ep.v_down[t, n][:, None]
    f = ep.f[None, :]
    wl = rho * z
    delay = (d / v_up + d_out / v_down + wl / f
             + (qs.q_prev + qs.q_bef)[None, :] / f)
    return jnp.argmin(delay, axis=1).astype(jnp.int32)


def build_episode_fn(method: str, p: envlib.EnvParams,
                     cfg: ag.AgentConfig, train: bool = True) -> Callable:
    """Returns jit-able episode(states, ep_data, key) ->
    (states, avg_delay, metrics)."""
    learned = method in LEARNED
    if learned:
        _, act, update, add_replay, latent = make_agent_fns(method, cfg)
        vact = jax.vmap(act, in_axes=(0, 0, None, 0, None))
        vupdate = jax.vmap(update, in_axes=(0, 0))
        vadd = jax.vmap(add_replay, in_axes=(0, 0, 0))
        vlatent = jax.vmap(latent, in_axes=(0, None))
    scale = envlib.state_scale(p)

    def episode(states, ep: envlib.EpisodeData, key):
        qs0 = envlib.init_queues(p)
        zB = jnp.zeros((p.num_bs,), jnp.float32)
        pend0 = Pending(s=jnp.zeros((p.num_bs, p.state_dim)),
                        x=jnp.zeros((p.num_bs, p.action_dim)),
                        a=jnp.zeros((p.num_bs,), jnp.int32), r=zB,
                        valid=jnp.zeros((p.num_bs,), bool))

        def task_step(carry, tn):
            states, qs, pend, av, key = carry
            t, n = tn
            key, k_act, k_upd = jax.random.split(key, 3)
            d = ep.d[t, n]
            workload = ep.rho[t, n] * ep.z[t, n]
            mask = ep.mask[t, n] > 0
            s = envlib.observe(p, qs, d, workload,
                               slack=ep.deadline[t, n],
                               f=ep.f, avail=av) / scale[None, :]

            if learned:
                x_next_lat = vlatent(states, n) if method == "lad-ts" else \
                    jnp.zeros((p.num_bs, p.action_dim))
                # complete the pending transition with s_next = s
                trans = ag.Transition(s=pend.s, x=pend.x, a=pend.a,
                                      r=pend.r, s_next=s, x_next=x_next_lat)
                states = vadd(states, trans, pend.valid)
                # NOTE: evaluation also samples from pi.  Greedy eval makes
                # all B schedulers herd onto the same fast ES and queues
                # explode (measured 0.3 -> 2.25s avg delay); the learned
                # policy is a stochastic load balancer by construction.
                keys = jax.random.split(k_act, p.num_bs)
                actions, x_used, states = vact(states, s, n, keys, False)
            else:
                actions = heuristic_actions(method, p, ep, qs, t, n, k_act)
                x_used = jnp.zeros((p.num_bs, p.action_dim))

            actions = actions % p.num_bs
            if p.has_faults:
                # the agent OWNS its choice: the chosen action goes into
                # replay (so it learns the wrong-choice penalty), while
                # the cluster EXECUTES the availability-masked remap
                executed, wrong = envlib.mask_actions(
                    av, qs.q_prev + qs.q_bef, actions)
                penalty = p.fault.penalty_s * wrong
            else:
                executed, penalty = actions, 0.0
            delays = envlib.task_delays(p, ep, qs, t, n, executed) + penalty
            # Eqn (9), priority-weighted (priority == 1 without QoS) with
            # an optional deadline-miss penalty
            r = -delays * cfg.reward_scale * ep.priority[t, n]
            if p.deadline_penalty:
                r -= (cfg.reward_scale * p.deadline_penalty
                      * ep.priority[t, n]
                      * (delays > ep.deadline[t, n]))
            qs = envlib.apply_actions(p, ep, qs, t, n, executed)

            if learned and train:
                size = states.replay.size                     # (B,)
                do_train = size > cfg.train_after

                def trained(states):
                    ukeys = jax.random.split(k_upd, p.num_bs)
                    new, _ = vupdate(states, ukeys)
                    return new

                new_states = trained(states)
                states = jax.tree_util.tree_map(
                    lambda a, b: jnp.where(
                        do_train.reshape((-1,) + (1,) * (a.ndim - 1))
                        if a.ndim else do_train.any(), b, a),
                    states, new_states)

            pend = Pending(s=s, x=x_used, a=actions, r=r, valid=mask)
            stats = (jnp.sum(delays * ep.mask[t, n]), jnp.sum(ep.mask[t, n]))
            return (states, qs, pend, av, key), stats

        def slot_step(carry, t):
            states, qs, pend, av, key = carry
            ns = jnp.arange(p.max_tasks)
            (states, qs, pend, av, key), stats = jax.lax.scan(
                task_step, (states, qs, pend, av, key),
                (jnp.full_like(ns, t), ns))
            if p.has_faults:
                qs = envlib.end_slot(p, ep, qs, avail=av)
                av = envlib.step_avail(p.fault, av, ep.avail_u[t])
            else:
                qs = envlib.end_slot(p, ep, qs)
            return (states, qs, pend, av, key), stats

        av0 = envlib.init_avail(p.num_bs)
        (states, qs, pend, av, key), stats = jax.lax.scan(
            slot_step, (states, qs0, pend0, av0, key), jnp.arange(p.num_slots))
        tot_delay = stats[0].sum()
        tot_tasks = stats[1].sum()
        return states, tot_delay / jnp.maximum(tot_tasks, 1.0)

    return episode


def init_agents(method: str, p: envlib.EnvParams, cfg: ag.AgentConfig,
                key):
    if method not in LEARNED:
        return None
    init, *_ = make_agent_fns(method, cfg)
    keys = jax.random.split(key, p.num_bs)
    return jax.vmap(lambda k: init(k, p.state_dim, p.action_dim,
                                   p.max_tasks))(keys)


def train_method(method: str, p: envlib.EnvParams, cfg: ag.AgentConfig,
                 episodes: int, key, verbose: bool = False, f=None):
    """Full training run.  Returns (per-episode avg delays, final states).

    ES capacities ``f`` are sampled once (hardware is fixed across
    episodes); pass the same ``f`` to evaluate_method."""
    key, k_init, k_f = jax.random.split(key, 3)
    if f is None:
        f = envlib.sample_capacities(k_f, p)
    states = init_agents(method, p, cfg, k_init)
    episode = jax.jit(build_episode_fn(method, p, cfg, train=True))
    delays = []
    for e in range(episodes):
        key, k_ep, k_run = jax.random.split(key, 3)
        ep_data = envlib.sample_episode(k_ep, p, f=f)
        t0 = time.time()
        states, avg = episode(states, ep_data, k_run)
        avg = float(avg)
        delays.append(avg)
        if verbose:
            print(f"[{method}] episode {e:3d} avg_delay={avg:7.3f}s "
                  f"({time.time()-t0:.1f}s wall)", flush=True)
    return delays, states


def evaluate_method(method: str, p: envlib.EnvParams, cfg: ag.AgentConfig,
                    states, key, n_episodes: int = 5, f=None) -> float:
    """Average delay over fresh episodes without training updates."""
    episode = jax.jit(build_episode_fn(method, p, cfg, train=False))
    tot = 0.0
    if f is None:
        _, k_f = jax.random.split(jax.random.key(0))
        f = envlib.sample_capacities(k_f, p)
    for e in range(n_episodes):
        key, k_ep, k_run = jax.random.split(key, 3)
        ep_data = envlib.sample_episode(k_ep, p, f=f)
        _, avg = episode(states, ep_data, k_run)
        tot += float(avg)
    return tot / n_episodes
