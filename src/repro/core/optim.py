"""Small functional Adam for the RL networks (paper uses Adam, Table IV)."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def adam_init(params) -> AdamState:
    z = lambda t: jax.tree_util.tree_map(jnp.zeros_like, t)  # noqa: E731
    return AdamState(step=jnp.zeros((), jnp.int32), mu=z(params),
                     nu=z(params))


def adam_update(params, grads, state: AdamState, lr: float,
                b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8):
    step = state.step + 1
    t = step.astype(jnp.float32)
    mu = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g,
                                state.mu, grads)
    nu = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g,
                                state.nu, grads)
    b1c = 1 - b1 ** t
    b2c = 1 - b2 ** t
    new_params = jax.tree_util.tree_map(
        lambda p, m, v: p - lr * (m / b1c) / (jnp.sqrt(v / b2c) + eps),
        params, mu, nu)
    return new_params, AdamState(step=step, mu=mu, nu=nu)
