"""Latent-action diffusion machinery (paper §IV-A, Theorem 2).

Forward-process variance schedule (VP-SDE discretisation, as in the paper):

    beta_i = 1 - exp(-beta_min/I - (2i-1)/(2I^2) (beta_max - beta_min))

Reverse update (Eqn 10), i = I..1:

    x_{i-1} = (x_i - beta_i/sqrt(1-lambda_bar_i) * eps_theta(x_i,i,s))
              / sqrt(lambda_i)  +  (beta_tilde_i/2) * eps

The paper uses the (beta_tilde_i / 2) * eps noise term verbatim; standard
DDPM samples with sqrt(beta_tilde_i) * eps — both are provided
(``paper_variance`` flag, default True for faithfulness).

The *latent action* strategy replaces the x_I ~ N(0, I) initialisation of
the reverse chain with the previous x_0 for the same (BS, task-slot) pair
(stored in the X_b array), which is the paper's key accelerator.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class DiffusionSchedule(NamedTuple):
    betas: jnp.ndarray          # (I,) beta_1..beta_I  (index 0 = i=1)
    lambdas: jnp.ndarray        # 1 - beta
    lambda_bars: jnp.ndarray    # cumprod lambda
    beta_tildes: jnp.ndarray    # posterior variances

    @property
    def num_steps(self) -> int:
        return self.betas.shape[0]


def make_schedule(num_steps: int, beta_min: float = 0.1,
                  beta_max: float = 10.0) -> DiffusionSchedule:
    i = jnp.arange(1, num_steps + 1, dtype=jnp.float32)
    I = float(num_steps)  # noqa: E741
    betas = 1.0 - jnp.exp(-beta_min / I
                          - (2 * i - 1) / (2 * I * I) * (beta_max - beta_min))
    lambdas = 1.0 - betas
    lambda_bars = jnp.cumprod(lambdas)
    prev_bars = jnp.concatenate([jnp.ones((1,)), lambda_bars[:-1]])
    beta_tildes = (1.0 - prev_bars) / (1.0 - lambda_bars) * betas
    return DiffusionSchedule(betas, lambdas, lambda_bars, beta_tildes)


def make_schedule_np(num_steps: int, beta_min: float = 0.1,
                     beta_max: float = 10.0) -> DiffusionSchedule:
    """Numpy twin of make_schedule — safe to evaluate at jit-trace time
    (the Pallas kernel folds the constants into immediates)."""
    import numpy as np
    i = np.arange(1, num_steps + 1, dtype=np.float32)
    I = float(num_steps)  # noqa: E741
    betas = 1.0 - np.exp(-beta_min / I
                         - (2 * i - 1) / (2 * I * I) * (beta_max - beta_min))
    lambdas = 1.0 - betas
    lambda_bars = np.cumprod(lambdas)
    prev_bars = np.concatenate([np.ones((1,), np.float32),
                                lambda_bars[:-1]])
    beta_tildes = (1.0 - prev_bars) / (1.0 - lambda_bars) * betas
    return DiffusionSchedule(betas, lambdas, lambda_bars, beta_tildes)


def forward_sample(sched: DiffusionSchedule, x0, i, eps):
    """Eqn (11): x_i = sqrt(lambda_bar_i) x_0 + sqrt(1-lambda_bar_i) eps.

    ``i`` is 1-based (array index i-1)."""
    lb = sched.lambda_bars[i - 1]
    return jnp.sqrt(lb) * x0 + jnp.sqrt(1.0 - lb) * eps


def reverse_step(sched: DiffusionSchedule, eps_pred, x_i, i, noise,
                 paper_variance: bool = True):
    """One Eqn-(10) update from x_i to x_{i-1}; ``i`` is 1-based."""
    idx = i - 1
    beta = sched.betas[idx]
    lam = sched.lambdas[idx]
    lbar = sched.lambda_bars[idx]
    btilde = sched.beta_tildes[idx]
    mean = (x_i - beta / jnp.sqrt(1.0 - lbar) * eps_pred) / jnp.sqrt(lam)
    if paper_variance:
        scale = btilde / 2.0
    else:
        scale = jnp.sqrt(btilde)
    # no noise on the final (i=1) step, as in DDPM sampling
    scale = jnp.where(i > 1, scale, 0.0)
    return mean + scale * noise


@dataclasses.dataclass(frozen=True)
class DiffusionPolicyConfig:
    num_steps: int = 5            # I (paper Table IV)
    beta_min: float = 0.1
    beta_max: float = 10.0
    paper_variance: bool = True
    latent_init: bool = True      # False -> D2SAC (Gaussian-noise init)


def run_reverse_chain(sched: DiffusionSchedule, eps_fn, x_I, s, key,
                      paper_variance: bool = True) -> Tuple[jnp.ndarray,
                                                            jnp.ndarray]:
    """Full reverse chain.  ``eps_fn(x, i, s) -> eps`` is the LADN.

    Returns (x_0, action probabilities softmax(x_0)).
    Differentiable end-to-end (reparameterised noise).
    """
    I = sched.num_steps  # noqa: E741
    noises = jax.random.normal(key, (I,) + x_I.shape)

    def body(x, step):
        i = I - step                      # I, I-1, ..., 1
        eps_pred = eps_fn(x, i, s)
        x_next = reverse_step(sched, eps_pred, x, i, noises[step],
                              paper_variance=paper_variance)
        return x_next, None

    x0, _ = jax.lax.scan(body, x_I, jnp.arange(I))
    probs = jax.nn.softmax(x0, axis=-1)
    return x0, probs
