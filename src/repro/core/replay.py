"""Fixed-capacity circular experience pool (paper: |R| = 1000), functional
and vmap-friendly (one pool per ES agent)."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class ReplayState(NamedTuple):
    data: Any              # pytree of (capacity, ...) arrays
    ptr: jnp.ndarray       # () int32 next write slot
    size: jnp.ndarray      # () int32 number of valid entries


def replay_init(capacity: int, item_spec) -> ReplayState:
    data = jax.tree_util.tree_map(
        lambda s: jnp.zeros((capacity,) + tuple(s.shape), s.dtype),
        item_spec)
    return ReplayState(data=data, ptr=jnp.zeros((), jnp.int32),
                       size=jnp.zeros((), jnp.int32))


def replay_add(state: ReplayState, item, valid) -> ReplayState:
    """Append ``item`` if ``valid`` (a traced bool), else no-op."""
    cap = jax.tree_util.tree_leaves(state.data)[0].shape[0]
    valid = jnp.asarray(valid)

    def write(buf, x):
        cur = buf[state.ptr]
        newv = jnp.where(
            valid.reshape((-1,) + (1,) * (x.ndim))[0]
            if x.ndim else valid, x, cur)
        return buf.at[state.ptr].set(newv)

    data = jax.tree_util.tree_map(write, state.data, item)
    inc = valid.astype(jnp.int32)
    return ReplayState(
        data=data,
        ptr=(state.ptr + inc) % cap,
        size=jnp.minimum(state.size + inc, cap),
    )


def replay_sample(state: ReplayState, key, batch: int):
    """Uniform sample of ``batch`` items from the valid prefix."""
    hi = jnp.maximum(state.size, 1)
    idx = jax.random.randint(key, (batch,), 0, hi)
    return jax.tree_util.tree_map(lambda buf: buf[idx], state.data)
