"""AIGC edge-offloading environment (paper §III, Eqns (1)-(9)).

System model: B base stations, each with an edge server running an AIGC
service.  At each time slot t, N_{b,t} AIGC tasks arrive at BS b; a
scheduler assigns each task to an ES b'.  The service delay of a task
(Eqn 2) is

    T = d_n / v_up  +  rho_n * z_n / f_b'  +  T_wait  +  d~_n / v_down
    T_wait = (q_{t-1,b'} + q_bef) / f_b'                       (Eqn 3)

and per-ES queues evolve by Eqn (4):

    q_t,b' = max(q_{t-1,b'} + sum workloads placed on b' - f_b' * Delta, 0)

AIGC task model: the workload is rho_n * z_n where z_n is the number of
denoising steps demanded (image-quality proxy) and rho_n the cycles per
step — workload depends on model complexity, not input size (paper's
"first challenge").

The environment is fully vectorised JAX: an episode is one (T x N_max x B)
scan; within a slot, the n-th tasks of all B stations are decided
simultaneously against the queue state accumulated from tasks 1..n-1 (the
paper's per-BS parallel / per-task sequential semantics).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

# re-exported so env consumers drive the fault process through envlib
from repro.faults.simfault import (FaultParams, init_avail,  # noqa: F401
                                   mask_actions, step_avail)


@dataclasses.dataclass(frozen=True)
class EnvParams:
    """Defaults follow Table III of the paper.

    ``qos_mix`` (a tuple of ``(QoSClass, weight)`` pairs, see
    ``repro.workload.qos``) switches on the heterogeneous-QoS extension:
    each task additionally samples a service class (per-class quality
    demand z_n, deadline budget, priority weight), the observation grows
    deadline-slack and per-ES model-affinity features (state layout
    ``[d, rho*z, q_1..q_B, slack, rho*z/f_1..rho*z/f_B]``), rewards are
    priority-weighted, and ``deadline_penalty`` optionally adds a miss
    penalty to Eqn (9).  With an empty mix everything reduces exactly to
    the paper's setup.

    ``fault`` (a :class:`repro.faults.FaultParams`) switches on the
    availability extension: every ES runs an independent Bernoulli
    up/down chain inside the episode scan, DOWN servers stop draining
    (Eqn 4's ``f`` term gated), the observation grows per-ES
    availability columns ``[.., a_1..a_B]`` appended LAST, and actions
    landing on a DOWN server are remapped to the least-loaded available
    one with ``penalty_s`` added to the task's delay.  ``fault=None``
    reproduces the legacy environment bit-for-bit, same as ``qos_mix``.
    """

    num_bs: int = 20                 # B
    num_slots: int = 60              # |T|
    slot_seconds: float = 1.0        # Delta
    max_tasks: int = 50              # N_{b,t} ~ U[1, max_tasks]
    min_tasks: int = 1
    # task data size d_n in Mbits ~ U[2, 5]; result size d~_n ~ U[0.6, 1.0]
    d_range: Tuple[float, float] = (2.0, 5.0)
    d_out_range: Tuple[float, float] = (0.6, 1.0)
    # quality demand z_n (denoising steps) ~ U[1, 15]
    z_range: Tuple[float, float] = (1.0, 15.0)
    # computing density rho_n in cycles/step, scaled so workloads are in
    # Gcycles: U[100, 300] cycles/bit-step against Mbit-scale tasks ->
    # rho*z in [0.1, 4.5] Gcycles per task (paper's units).
    rho_range: Tuple[float, float] = (0.1, 0.3)
    # transmission rate v in Mbit/s ~ U[400, 500]
    v_range: Tuple[float, float] = (400.0, 500.0)
    # ES capacity f_b' in Gcycles/s ~ U[10, 50] GHz
    f_range: Tuple[float, float] = (10.0, 50.0)
    # The paper motivates the latent store by tasks having "a specific
    # periodic pattern over a certain period": 0.0 = fully iid tasks,
    # 1.0 = task slot n always carries the same (d, z, rho) demand.
    task_periodicity: float = 0.0
    # QoS extension (repro.workload): () = plain paper env
    qos_mix: Tuple[Tuple[Any, float], ...] = ()
    slack_cap: float = 10.0          # seconds; clamps inf deadlines
    deadline_penalty: float = 0.0    # extra -reward per missed deadline
    # fault extension (repro.faults): None = permanently healthy ESs
    fault: Optional[FaultParams] = None

    @property
    def has_qos(self) -> bool:
        return len(self.qos_mix) > 0

    @property
    def has_faults(self) -> bool:
        return self.fault is not None

    @property
    def z_hi(self) -> float:
        """Largest quality demand across base range and QoS classes."""
        z = self.z_range[1]
        for c, _ in self.qos_mix:
            z = max(z, c.z_range[1])
        return float(z)

    @property
    def state_dim(self) -> int:
        # s = [d_n, rho_n * z_n, q_{t-1,1..B}]  (Eqn 6)
        # + [slack, rho_n * z_n / f_1..B] when QoS classes are active
        # + [a_1..B] availability (appended LAST) when faults are active
        base = 2 + self.num_bs
        return (base + (1 + self.num_bs if self.has_qos else 0)
                + (self.num_bs if self.has_faults else 0))

    @property
    def action_dim(self) -> int:
        return self.num_bs


class EpisodeData(NamedTuple):
    """Pre-sampled randomness for one episode (shapes lead with T, N, B)."""

    d: jnp.ndarray        # (T, N, B) input Mbits
    d_out: jnp.ndarray    # (T, N, B) result Mbits
    z: jnp.ndarray        # (T, N, B) denoising steps
    rho: jnp.ndarray      # (T, N, B) Gcycles per step
    v_up: jnp.ndarray     # (T, N, B) Mbit/s
    v_down: jnp.ndarray   # (T, N, B) Mbit/s
    mask: jnp.ndarray     # (T, N, B) task exists
    f: jnp.ndarray        # (B,) ES capacity Gcycles/s
    # QoS extension (constants when EnvParams.qos_mix is empty)
    cls: jnp.ndarray      # (T, N, B) int32 class index (0 without QoS)
    deadline: jnp.ndarray  # (T, N, B) service budget, inf = best-effort
    priority: jnp.ndarray  # (T, N, B) priority weight (1 without QoS)
    # fault extension: per-slot uniforms driving the Bernoulli up/down
    # chain (drawn from a folded key, so every legacy field is
    # bit-identical whether or not faults are enabled)
    avail_u: jnp.ndarray  # (T, B) U[0,1)


def sample_capacities(key, p: EnvParams) -> jnp.ndarray:
    """Per-ES compute capacities — hardware, so sampled ONCE per
    environment instance and held fixed across episodes ('reset system
    environment' in Algorithm 1 resets queues, not the cluster)."""
    return jax.random.uniform(key, (p.num_bs,), jnp.float32, *p.f_range)


def sample_episode(key, p: EnvParams, f=None) -> EpisodeData:
    ks = jax.random.split(key, 12)
    shape = (p.num_slots, p.max_tasks, p.num_bs)

    def u(k, lo, hi, s=shape):
        return jax.random.uniform(k, s, jnp.float32, lo, hi)

    def periodic(k_base, k_iid, lo, hi):
        """Blend a per-(task-slot, BS) base demand with iid noise."""
        iid = u(k_iid, lo, hi)
        if p.task_periodicity <= 0.0:
            return iid
        base = jax.random.uniform(k_base, (1, p.max_tasks, p.num_bs),
                                  jnp.float32, lo, hi)
        w = p.task_periodicity
        return w * jnp.broadcast_to(base, shape) + (1 - w) * iid

    n_tasks = jax.random.randint(ks[0], (p.num_slots, p.num_bs),
                                 p.min_tasks, p.max_tasks + 1)
    mask = (jnp.arange(p.max_tasks)[None, :, None]
            < n_tasks[:, None, :]).astype(jnp.float32)
    if p.has_qos:
        classes = [c for c, _ in p.qos_mix]
        w = jnp.asarray([x for _, x in p.qos_mix], jnp.float32)
        cls = jax.random.categorical(ks[11], jnp.log(w / w.sum()),
                                     shape=shape)
        z_lo = jnp.asarray([c.z_range[0] for c in classes], jnp.float32)
        z_hi = jnp.asarray([c.z_range[1] for c in classes], jnp.float32)
        z = jnp.round(z_lo[cls] + u(ks[3], 0.0, 1.0)
                      * (z_hi[cls] - z_lo[cls]))
        deadline = jnp.asarray(
            [c.deadline_s if math.isfinite(c.deadline_s) else jnp.inf
             for c in classes], jnp.float32)[cls]
        priority = jnp.asarray([c.priority for c in classes],
                               jnp.float32)[cls]
    else:
        cls = jnp.zeros(shape, jnp.int32)
        z = jnp.round(periodic(ks[9], ks[3], *p.z_range))
        deadline = jnp.full(shape, jnp.inf, jnp.float32)
        priority = jnp.ones(shape, jnp.float32)
    return EpisodeData(
        d=periodic(ks[8], ks[1], *p.d_range),
        d_out=u(ks[2], *p.d_out_range),
        z=z,
        rho=periodic(ks[10], ks[4], *p.rho_range),
        v_up=u(ks[5], *p.v_range),
        v_down=u(ks[6], *p.v_range),
        mask=mask,
        f=f if f is not None else sample_capacities(ks[7], p),
        cls=cls.astype(jnp.int32),
        deadline=deadline,
        priority=priority,
        avail_u=jax.random.uniform(jax.random.fold_in(key, 0xFA),
                                   (p.num_slots, p.num_bs)),
    )


class QueueState(NamedTuple):
    q_prev: jnp.ndarray   # (B,) queue length at end of slot t-1 (Gcycles)
    q_bef: jnp.ndarray    # (B,) workload placed on each ES so far in slot t


def init_queues(p: EnvParams) -> QueueState:
    z = jnp.zeros((p.num_bs,), jnp.float32)
    return QueueState(q_prev=z, q_bef=z)


def observe(p: EnvParams, qs: QueueState, d, workload,
            slack=None, f=None, avail=None) -> jnp.ndarray:
    """Per-task state vector (Eqn 6), vectorised over the B stations.

    d, workload: (B,) — the n-th task of each BS.  Returns (B, state_dim).

    With QoS enabled the row is extended by a deadline-slack scalar
    (remaining budget, clamped at ``slack_cap``) and per-ES affinity
    features ``workload / f_b'`` — the task's expected compute seconds on
    each target, which is what makes heterogeneous capacities visible to
    the policy before queues build up.

    With faults enabled the row additionally carries the per-ES
    availability vector (appended LAST, matching the live cluster) so a
    policy can learn to steer around DOWN servers.
    """
    qrep = jnp.broadcast_to(qs.q_prev[None, :], (p.num_bs, p.num_bs))
    cols = [d[:, None], workload[:, None], qrep]
    if p.has_qos:
        if slack is None or f is None:
            raise ValueError("QoS-enabled EnvParams: observe() needs the "
                             "per-task deadline slack and capacities f")
        cols.append(jnp.minimum(slack, p.slack_cap)[:, None])
        cols.append(workload[:, None] / f[None, :])
    if p.has_faults:
        if avail is None:
            raise ValueError("fault-enabled EnvParams: observe() needs "
                             "the per-ES availability vector")
        cols.append(jnp.broadcast_to(avail[None, :],
                                     (p.num_bs, p.num_bs)))
    return jnp.concatenate(cols, axis=1)


def task_delays(p: EnvParams, ep: EpisodeData, qs: QueueState, t, n,
                actions: jnp.ndarray) -> jnp.ndarray:
    """Service delay (Eqn 2) of the n-th task of every BS given one-hot-
    index actions (B,) in [0, B).  Returns (B,) delays in seconds."""
    d = ep.d[t, n]                    # (B,)
    z = ep.z[t, n]
    rho = ep.rho[t, n]
    d_out = ep.d_out[t, n]
    v_up = ep.v_up[t, n]
    v_down = ep.v_down[t, n]
    f_tgt = ep.f[actions]             # (B,)
    workload = rho * z                # Gcycles
    t_tx = d / v_up + d_out / v_down
    t_comp = workload / f_tgt
    t_wait = (qs.q_prev[actions] + qs.q_bef[actions]) / f_tgt   # Eqn (3)
    return t_tx + t_comp + t_wait


def apply_actions(p: EnvParams, ep: EpisodeData, qs: QueueState, t, n,
                  actions: jnp.ndarray) -> QueueState:
    """Accumulate the placed workloads into the in-slot queue."""
    workload = ep.rho[t, n] * ep.z[t, n] * ep.mask[t, n]       # (B,)
    placed = jnp.zeros((p.num_bs,), jnp.float32).at[actions].add(workload)
    return QueueState(q_prev=qs.q_prev, q_bef=qs.q_bef + placed)


def end_slot(p: EnvParams, ep: EpisodeData, qs: QueueState,
             avail=None) -> QueueState:
    """Queue update at slot end (Eqn 4).

    With faults enabled the caller passes the per-ES availability vector
    and DOWN servers (avail == 0) drain nothing this slot — their backlog
    carries over untouched until they come back up.
    """
    f = ep.f if avail is None else ep.f * avail
    q = jnp.maximum(qs.q_prev + qs.q_bef - f * p.slot_seconds, 0.0)
    return QueueState(q_prev=q, q_bef=jnp.zeros_like(qs.q_bef))


def state_scale(p: EnvParams) -> jnp.ndarray:
    """Feature normalisation for the networks (keeps inputs O(1))."""
    d_hi = p.d_range[1]
    w_hi = p.rho_range[1] * p.z_hi
    q_hi = p.rho_range[1] * p.z_hi * p.max_tasks  # rough slot load
    parts = [
        jnp.array([d_hi, w_hi], jnp.float32),
        jnp.full((p.num_bs,), q_hi, jnp.float32),
    ]
    if p.has_qos:
        parts.append(jnp.array([p.slack_cap], jnp.float32))
        parts.append(jnp.full((p.num_bs,), w_hi / p.f_range[0],
                              jnp.float32))
    if p.has_faults:
        parts.append(jnp.ones((p.num_bs,), jnp.float32))
    return jnp.concatenate(parts)
