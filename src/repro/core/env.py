"""AIGC edge-offloading environment (paper §III, Eqns (1)-(9)).

System model: B base stations, each with an edge server running an AIGC
service.  At each time slot t, N_{b,t} AIGC tasks arrive at BS b; a
scheduler assigns each task to an ES b'.  The service delay of a task
(Eqn 2) is

    T = d_n / v_up  +  rho_n * z_n / f_b'  +  T_wait  +  d~_n / v_down
    T_wait = (q_{t-1,b'} + q_bef) / f_b'                       (Eqn 3)

and per-ES queues evolve by Eqn (4):

    q_t,b' = max(q_{t-1,b'} + sum workloads placed on b' - f_b' * Delta, 0)

AIGC task model: the workload is rho_n * z_n where z_n is the number of
denoising steps demanded (image-quality proxy) and rho_n the cycles per
step — workload depends on model complexity, not input size (paper's
"first challenge").

The environment is fully vectorised JAX: an episode is one (T x N_max x B)
scan; within a slot, the n-th tasks of all B stations are decided
simultaneously against the queue state accumulated from tasks 1..n-1 (the
paper's per-BS parallel / per-task sequential semantics).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class EnvParams:
    """Defaults follow Table III of the paper."""

    num_bs: int = 20                 # B
    num_slots: int = 60              # |T|
    slot_seconds: float = 1.0        # Delta
    max_tasks: int = 50              # N_{b,t} ~ U[1, max_tasks]
    min_tasks: int = 1
    # task data size d_n in Mbits ~ U[2, 5]; result size d~_n ~ U[0.6, 1.0]
    d_range: Tuple[float, float] = (2.0, 5.0)
    d_out_range: Tuple[float, float] = (0.6, 1.0)
    # quality demand z_n (denoising steps) ~ U[1, 15]
    z_range: Tuple[float, float] = (1.0, 15.0)
    # computing density rho_n in cycles/step, scaled so workloads are in
    # Gcycles: U[100, 300] cycles/bit-step against Mbit-scale tasks ->
    # rho*z in [0.1, 4.5] Gcycles per task (paper's units).
    rho_range: Tuple[float, float] = (0.1, 0.3)
    # transmission rate v in Mbit/s ~ U[400, 500]
    v_range: Tuple[float, float] = (400.0, 500.0)
    # ES capacity f_b' in Gcycles/s ~ U[10, 50] GHz
    f_range: Tuple[float, float] = (10.0, 50.0)
    # The paper motivates the latent store by tasks having "a specific
    # periodic pattern over a certain period": 0.0 = fully iid tasks,
    # 1.0 = task slot n always carries the same (d, z, rho) demand.
    task_periodicity: float = 0.0

    @property
    def state_dim(self) -> int:
        # s = [d_n, rho_n * z_n, q_{t-1,1..B}]  (Eqn 6)
        return 2 + self.num_bs

    @property
    def action_dim(self) -> int:
        return self.num_bs


class EpisodeData(NamedTuple):
    """Pre-sampled randomness for one episode (shapes lead with T, N, B)."""

    d: jnp.ndarray        # (T, N, B) input Mbits
    d_out: jnp.ndarray    # (T, N, B) result Mbits
    z: jnp.ndarray        # (T, N, B) denoising steps
    rho: jnp.ndarray      # (T, N, B) Gcycles per step
    v_up: jnp.ndarray     # (T, N, B) Mbit/s
    v_down: jnp.ndarray   # (T, N, B) Mbit/s
    mask: jnp.ndarray     # (T, N, B) task exists
    f: jnp.ndarray        # (B,) ES capacity Gcycles/s


def sample_capacities(key, p: EnvParams) -> jnp.ndarray:
    """Per-ES compute capacities — hardware, so sampled ONCE per
    environment instance and held fixed across episodes ('reset system
    environment' in Algorithm 1 resets queues, not the cluster)."""
    return jax.random.uniform(key, (p.num_bs,), jnp.float32, *p.f_range)


def sample_episode(key, p: EnvParams, f=None) -> EpisodeData:
    ks = jax.random.split(key, 12)
    shape = (p.num_slots, p.max_tasks, p.num_bs)

    def u(k, lo, hi, s=shape):
        return jax.random.uniform(k, s, jnp.float32, lo, hi)

    def periodic(k_base, k_iid, lo, hi):
        """Blend a per-(task-slot, BS) base demand with iid noise."""
        iid = u(k_iid, lo, hi)
        if p.task_periodicity <= 0.0:
            return iid
        base = jax.random.uniform(k_base, (1, p.max_tasks, p.num_bs),
                                  jnp.float32, lo, hi)
        w = p.task_periodicity
        return w * jnp.broadcast_to(base, shape) + (1 - w) * iid

    n_tasks = jax.random.randint(ks[0], (p.num_slots, p.num_bs),
                                 p.min_tasks, p.max_tasks + 1)
    mask = (jnp.arange(p.max_tasks)[None, :, None]
            < n_tasks[:, None, :]).astype(jnp.float32)
    return EpisodeData(
        d=periodic(ks[8], ks[1], *p.d_range),
        d_out=u(ks[2], *p.d_out_range),
        z=jnp.round(periodic(ks[9], ks[3], *p.z_range)),
        rho=periodic(ks[10], ks[4], *p.rho_range),
        v_up=u(ks[5], *p.v_range),
        v_down=u(ks[6], *p.v_range),
        mask=mask,
        f=f if f is not None else sample_capacities(ks[7], p),
    )


class QueueState(NamedTuple):
    q_prev: jnp.ndarray   # (B,) queue length at end of slot t-1 (Gcycles)
    q_bef: jnp.ndarray    # (B,) workload placed on each ES so far in slot t


def init_queues(p: EnvParams) -> QueueState:
    z = jnp.zeros((p.num_bs,), jnp.float32)
    return QueueState(q_prev=z, q_bef=z)


def observe(p: EnvParams, qs: QueueState, d, workload) -> jnp.ndarray:
    """Per-task state vector (Eqn 6), vectorised over the B stations.

    d, workload: (B,) — the n-th task of each BS.  Returns (B, state_dim).
    """
    qrep = jnp.broadcast_to(qs.q_prev[None, :], (p.num_bs, p.num_bs))
    return jnp.concatenate([d[:, None], workload[:, None], qrep], axis=1)


def task_delays(p: EnvParams, ep: EpisodeData, qs: QueueState, t, n,
                actions: jnp.ndarray) -> jnp.ndarray:
    """Service delay (Eqn 2) of the n-th task of every BS given one-hot-
    index actions (B,) in [0, B).  Returns (B,) delays in seconds."""
    d = ep.d[t, n]                    # (B,)
    z = ep.z[t, n]
    rho = ep.rho[t, n]
    d_out = ep.d_out[t, n]
    v_up = ep.v_up[t, n]
    v_down = ep.v_down[t, n]
    f_tgt = ep.f[actions]             # (B,)
    workload = rho * z                # Gcycles
    t_tx = d / v_up + d_out / v_down
    t_comp = workload / f_tgt
    t_wait = (qs.q_prev[actions] + qs.q_bef[actions]) / f_tgt   # Eqn (3)
    return t_tx + t_comp + t_wait


def apply_actions(p: EnvParams, ep: EpisodeData, qs: QueueState, t, n,
                  actions: jnp.ndarray) -> QueueState:
    """Accumulate the placed workloads into the in-slot queue."""
    workload = ep.rho[t, n] * ep.z[t, n] * ep.mask[t, n]       # (B,)
    placed = jnp.zeros((p.num_bs,), jnp.float32).at[actions].add(workload)
    return QueueState(q_prev=qs.q_prev, q_bef=qs.q_bef + placed)


def end_slot(p: EnvParams, ep: EpisodeData, qs: QueueState) -> QueueState:
    """Queue update at slot end (Eqn 4)."""
    q = jnp.maximum(qs.q_prev + qs.q_bef - ep.f * p.slot_seconds, 0.0)
    return QueueState(q_prev=q, q_bef=jnp.zeros_like(qs.q_bef))


def state_scale(p: EnvParams) -> jnp.ndarray:
    """Feature normalisation for the networks (keeps inputs O(1))."""
    d_hi = p.d_range[1]
    w_hi = p.rho_range[1] * p.z_range[1]
    q_hi = p.rho_range[1] * p.z_range[1] * p.max_tasks  # rough slot load
    return jnp.concatenate([
        jnp.array([d_hi, w_hi], jnp.float32),
        jnp.full((p.num_bs,), q_hi, jnp.float32),
    ])
