"""Scheduling agents: LAD-TS (the paper), D2SAC-TS, SAC-TS, DQN-TS, and the
non-learned Opt-TS / Random-TS / Local-TS heuristics.

All agents are pure-functional over NamedTuple states so one jitted episode
scan can vmap them over the B per-ES schedulers (the paper's distributed
deployment: one agent / latent store / experience pool per edge server).

LAD-TS (paper §IV):
  * actor = LADN reverse-diffusion chain conditioned on the state, started
    from the *latent action* X_b[n] (last x_0 for the same task slot)
    instead of Gaussian noise;
  * critics / targets / entropy temperature follow discrete soft
    actor-critic (Eqns 14-17); the acting network theta~ (s-LADN) is a
    copy of the trained theta (t-LADN) refreshed after every update
    (Algorithm 1 line 18).

D2SAC-TS is LAD-TS with ``latent_init=False`` (chains start from noise and
the latent store is never read), matching Du et al.'s diffusion SAC.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import networks as nets
from repro.core.diffusion import (DiffusionPolicyConfig, make_schedule,
                                  run_reverse_chain)
from repro.core.optim import AdamState, adam_init, adam_update
from repro.core.replay import ReplayState, replay_add, replay_init, \
    replay_sample


@dataclasses.dataclass(frozen=True)
class AgentConfig:
    """Model hyper-parameters (paper Table IV)."""

    hidden: Tuple[int, ...] = (20, 20)
    lr_actor: float = 1e-4
    lr_critic: float = 1e-3
    lr_alpha: float = 3e-4
    gamma: float = 0.95
    tau: float = 0.005
    batch_size: int = 64
    replay_capacity: int = 1000
    train_after: int = 300          # |R| > 300 before updates (Alg. 1)
    init_alpha: float = 0.05
    target_entropy: float = -1.0
    # rewards are -delay (seconds); the scale conditions critic targets so
    # heavy-load envs (delays of tens of seconds) don't blow up the MSE.
    reward_scale: float = 0.1
    diffusion: DiffusionPolicyConfig = DiffusionPolicyConfig()
    # DQN-only
    eps_start: float = 0.9
    eps_end: float = 0.05
    eps_decay_steps: int = 2000


class Transition(NamedTuple):
    s: jnp.ndarray
    x: jnp.ndarray        # latent action x_I used for this decision
    a: jnp.ndarray        # () int32
    r: jnp.ndarray        # () f32
    s_next: jnp.ndarray
    x_next: jnp.ndarray


def transition_spec(state_dim: int, action_dim: int) -> Transition:
    f = jnp.zeros
    return Transition(s=f((state_dim,)), x=f((action_dim,)),
                      a=jnp.zeros((), jnp.int32), r=jnp.zeros(()),
                      s_next=f((state_dim,)), x_next=f((action_dim,)))


# ===========================================================================
# LAD-TS (and D2SAC-TS via cfg.diffusion.latent_init=False)
# ===========================================================================


class LadtsState(NamedTuple):
    theta: Any            # t-LADN (trained)
    theta_act: Any        # s-LADN (acting copy)
    c1: Any
    c2: Any
    t1: Any
    t2: Any
    log_alpha: jnp.ndarray
    opt_theta: AdamState
    opt_c1: AdamState
    opt_c2: AdamState
    opt_alpha: AdamState
    X: jnp.ndarray        # (N_max, A) latent action store
    replay: ReplayState
    steps: jnp.ndarray


def ladts_init(key, cfg: AgentConfig, state_dim: int, action_dim: int,
               n_max: int) -> LadtsState:
    ks = jax.random.split(key, 6)
    theta = nets.init_ladn(ks[0], state_dim, action_dim, cfg.hidden)
    c1 = nets.init_critic(ks[1], state_dim, action_dim, cfg.hidden)
    c2 = nets.init_critic(ks[2], state_dim, action_dim, cfg.hidden)
    X = jax.random.normal(ks[3], (n_max, action_dim))
    return LadtsState(
        theta=theta, theta_act=jax.tree_util.tree_map(lambda x: x, theta),
        c1=c1, c2=c2,
        t1=jax.tree_util.tree_map(lambda x: x, c1),
        t2=jax.tree_util.tree_map(lambda x: x, c2),
        log_alpha=jnp.log(jnp.asarray(cfg.init_alpha)),
        opt_theta=adam_init(theta), opt_c1=adam_init(c1),
        opt_c2=adam_init(c2),
        opt_alpha=adam_init(jnp.zeros(())),
        X=X,
        replay=replay_init(cfg.replay_capacity,
                           transition_spec(state_dim, action_dim)),
        steps=jnp.zeros((), jnp.int32),
    )


def _policy_probs(theta, cfg: AgentConfig, s, x_latent, key):
    """Differentiable pi(.|s, latent): reverse chain + softmax.

    ``x_latent`` is the RAW stored latent (or anything, ignored when
    latent_init=False).  The forward-process noising to level I (Eqn 11)
    happens HERE so acting and training evaluate the policy identically:
    x_I = sqrt(lbar_I) latent + sqrt(1-lbar_I) eps.  The reverse chain
    amplifies by 1/sqrt(lbar_I), so the prior enters the output at unit
    scale while fresh noise keeps decisions exploratory.

    s (..., S), x_latent (..., A) -> (x0, probs) with matching batch dims.
    """
    sched = make_schedule(cfg.diffusion.num_steps, cfg.diffusion.beta_min,
                          cfg.diffusion.beta_max)
    eps_fn = lambda x, i, ss: nets.apply_ladn(theta, x, i, ss)  # noqa: E731
    lbar = sched.lambda_bars[-1]

    def chain(xl, si, k):
        k_noise, k_chain = jax.random.split(k)
        eps0 = jax.random.normal(k_noise, xl.shape)
        if cfg.diffusion.latent_init:
            x_I = jnp.sqrt(lbar) * xl + jnp.sqrt(1 - lbar) * eps0
        else:
            x_I = eps0                      # D2SAC: pure Gaussian start
        return run_reverse_chain(sched, eps_fn, x_I, si, k_chain,
                                 cfg.diffusion.paper_variance)

    if x_latent.ndim == 1:
        return chain(x_latent, s, key)
    keys = jax.random.split(key, x_latent.shape[0])
    return jax.vmap(chain)(x_latent, s, keys)


def ladts_act(state: LadtsState, cfg: AgentConfig, s, n, key,
              greedy: bool = False) -> Tuple[jnp.ndarray, LadtsState]:
    """One decision for task slot ``n``.  s (S,) -> action () int32.

    Training-time actions are sampled from pi (Fig. 4's sampling unit) —
    pure Eqn-(8) argmax plus the latent store's self-reinforcement
    collapses every scheduler onto one ES and queues explode (observed
    empirically; see DESIGN.md §Deviations).  Evaluation uses argmax.
    """
    k_chain, k_samp = jax.random.split(key)
    x0, probs = _policy_probs(state.theta_act, cfg, s, state.X[n], k_chain)
    logp = jnp.log(jnp.clip(probs, 1e-8))
    a_greedy = jnp.argmax(probs, axis=-1).astype(jnp.int32)   # Eqn (8)
    a_sample = jax.random.categorical(k_samp, logp).astype(jnp.int32)
    a = jnp.where(greedy, a_greedy, a_sample)
    # Latent update: store the standardized x_0.  Raw x_0 compounds
    # exponentially across reuse (the reverse chain expands its input by
    # ~1/sqrt(lbar_I) ~ 12x at I=5) and saturates the policy; softmax(x_0)
    # over-flattens it.  Z-scoring preserves the action preference shape
    # at the N(0,1) scale the chain was initialised for (DESIGN.md
    # §Deviations).
    x0n = (x0 - x0.mean(-1, keepdims=True)) / (x0.std(-1, keepdims=True)
                                               + 1e-6)
    X = state.X.at[n].set(x0n)
    return a, state._replace(X=X)


def ladts_latent(state: LadtsState, n) -> jnp.ndarray:
    return state.X[n]


def ladts_update(state: LadtsState, cfg: AgentConfig, key
                 ) -> Tuple[LadtsState, dict]:
    k_samp, k_pi, k_pi_next, k_pi_actor = jax.random.split(key, 4)
    batch: Transition = replay_sample(state.replay, k_samp, cfg.batch_size)
    alpha = jnp.exp(state.log_alpha)
    gamma = cfg.gamma

    # --- target Q (Eqn after (13); discrete soft expectation form) --------
    _, probs_next = _policy_probs(state.theta, cfg, batch.s_next,
                                  batch.x_next, k_pi_next)
    logp_next = jnp.log(jnp.clip(probs_next, 1e-8))
    q1n = nets.apply_critic(state.t1, batch.s_next)
    q2n = nets.apply_critic(state.t2, batch.s_next)
    qn = jnp.minimum(q1n, q2n)
    h_next = -(probs_next * logp_next).sum(-1)
    v_next = (probs_next * qn).sum(-1) + alpha * h_next
    q_target = batch.r + gamma * v_next                   # (K,)
    q_target = jax.lax.stop_gradient(q_target)

    # --- critic update (Eqn 14) -------------------------------------------
    def critic_loss(cp):
        q = nets.apply_critic(cp, batch.s)
        qa = jnp.take_along_axis(q, batch.a[:, None], axis=1)[:, 0]
        return jnp.mean((qa - q_target) ** 2)

    lc1, g1 = jax.value_and_grad(critic_loss)(state.c1)
    lc2, g2 = jax.value_and_grad(critic_loss)(state.c2)
    c1, opt_c1 = adam_update(state.c1, g1, state.opt_c1, cfg.lr_critic)
    c2, opt_c2 = adam_update(state.c2, g2, state.opt_c2, cfg.lr_critic)

    # --- actor update (Eqn 15, standard discrete-SAC form; see DESIGN.md
    # §Deviations for the paper's squared variant) --------------------------
    q1e = nets.apply_critic(c1, batch.s)
    q2e = nets.apply_critic(c2, batch.s)
    q_eval = jax.lax.stop_gradient(jnp.minimum(q1e, q2e))

    def actor_loss(th):
        _, probs = _policy_probs(th, cfg, batch.s, batch.x, k_pi_actor)
        logp = jnp.log(jnp.clip(probs, 1e-8))
        return jnp.mean((probs * (alpha * logp - q_eval)).sum(-1))

    la, gth = jax.value_and_grad(actor_loss)(state.theta)
    theta, opt_theta = adam_update(state.theta, gth, state.opt_theta,
                                   cfg.lr_actor)

    # --- temperature update (Eqn 16) ---------------------------------------
    _, probs_now = _policy_probs(theta, cfg, batch.s, batch.x, k_pi)
    h_now = -(probs_now * jnp.log(jnp.clip(probs_now, 1e-8))).sum(-1).mean()
    h_now = jax.lax.stop_gradient(h_now)

    def alpha_loss(log_a):
        return jnp.exp(log_a) * (h_now - cfg.target_entropy)

    lal, ga = jax.value_and_grad(alpha_loss)(state.log_alpha)
    log_alpha, opt_alpha = adam_update(state.log_alpha, ga,
                                       state.opt_alpha, cfg.lr_alpha)

    # --- soft target update (Eqn 17) + s-LADN refresh ----------------------
    soft = lambda t, c: jax.tree_util.tree_map(  # noqa: E731
        lambda a, b: (1 - cfg.tau) * a + cfg.tau * b, t, c)
    new = state._replace(
        theta=theta, theta_act=theta, c1=c1, c2=c2,
        t1=soft(state.t1, c1), t2=soft(state.t2, c2),
        log_alpha=log_alpha, opt_theta=opt_theta, opt_c1=opt_c1,
        opt_c2=opt_c2, opt_alpha=opt_alpha, steps=state.steps + 1)
    metrics = {"critic_loss": (lc1 + lc2) / 2, "actor_loss": la,
               "alpha": jnp.exp(log_alpha), "entropy": h_now}
    return new, metrics


# ===========================================================================
# SAC-TS baseline: categorical MLP actor, same critic machinery
# ===========================================================================


class SacState(NamedTuple):
    actor: Any
    c1: Any
    c2: Any
    t1: Any
    t2: Any
    log_alpha: jnp.ndarray
    opt_actor: AdamState
    opt_c1: AdamState
    opt_c2: AdamState
    opt_alpha: AdamState
    replay: ReplayState
    steps: jnp.ndarray


def sac_init(key, cfg: AgentConfig, state_dim: int, action_dim: int,
             n_max: int) -> SacState:
    ks = jax.random.split(key, 3)
    actor = nets.init_mlp(ks[0], (state_dim, *cfg.hidden, action_dim))
    c1 = nets.init_critic(ks[1], state_dim, action_dim, cfg.hidden)
    c2 = nets.init_critic(ks[2], state_dim, action_dim, cfg.hidden)
    return SacState(
        actor=actor, c1=c1, c2=c2,
        t1=jax.tree_util.tree_map(lambda x: x, c1),
        t2=jax.tree_util.tree_map(lambda x: x, c2),
        log_alpha=jnp.log(jnp.asarray(cfg.init_alpha)),
        opt_actor=adam_init(actor), opt_c1=adam_init(c1),
        opt_c2=adam_init(c2), opt_alpha=adam_init(jnp.zeros(())),
        replay=replay_init(cfg.replay_capacity,
                           transition_spec(state_dim, action_dim)),
        steps=jnp.zeros((), jnp.int32),
    )


def sac_act(state: SacState, cfg: AgentConfig, s, key,
            greedy: bool = False) -> jnp.ndarray:
    logits = nets.apply_mlp(state.actor, s)
    a_s = jax.random.categorical(key, logits).astype(jnp.int32)
    return jnp.where(greedy, jnp.argmax(logits, -1).astype(jnp.int32), a_s)


def sac_update(state: SacState, cfg: AgentConfig, key
               ) -> Tuple[SacState, dict]:
    k_samp, _ = jax.random.split(key)
    batch: Transition = replay_sample(state.replay, k_samp, cfg.batch_size)
    alpha = jnp.exp(state.log_alpha)

    probs_next = jax.nn.softmax(nets.apply_mlp(state.actor, batch.s_next))
    logp_next = jnp.log(jnp.clip(probs_next, 1e-8))
    qn = jnp.minimum(nets.apply_critic(state.t1, batch.s_next),
                     nets.apply_critic(state.t2, batch.s_next))
    v_next = (probs_next * (qn - alpha * logp_next)).sum(-1)
    q_target = jax.lax.stop_gradient(batch.r + cfg.gamma * v_next)

    def critic_loss(cp):
        qa = jnp.take_along_axis(nets.apply_critic(cp, batch.s),
                                 batch.a[:, None], axis=1)[:, 0]
        return jnp.mean((qa - q_target) ** 2)

    lc1, g1 = jax.value_and_grad(critic_loss)(state.c1)
    lc2, g2 = jax.value_and_grad(critic_loss)(state.c2)
    c1, opt_c1 = adam_update(state.c1, g1, state.opt_c1, cfg.lr_critic)
    c2, opt_c2 = adam_update(state.c2, g2, state.opt_c2, cfg.lr_critic)

    q_eval = jax.lax.stop_gradient(
        jnp.minimum(nets.apply_critic(c1, batch.s),
                    nets.apply_critic(c2, batch.s)))

    def actor_loss(ap):
        probs = jax.nn.softmax(nets.apply_mlp(ap, batch.s))
        logp = jnp.log(jnp.clip(probs, 1e-8))
        return jnp.mean((probs * (alpha * logp - q_eval)).sum(-1))

    la, ga_ = jax.value_and_grad(actor_loss)(state.actor)
    actor, opt_actor = adam_update(state.actor, ga_, state.opt_actor,
                                   cfg.lr_actor)

    probs_now = jax.nn.softmax(nets.apply_mlp(actor, batch.s))
    h_now = -(probs_now
              * jnp.log(jnp.clip(probs_now, 1e-8))).sum(-1).mean()

    def alpha_loss(log_a):
        return jnp.exp(log_a) * (jax.lax.stop_gradient(h_now)
                                 - cfg.target_entropy)

    _, gal = jax.value_and_grad(alpha_loss)(state.log_alpha)
    log_alpha, opt_alpha = adam_update(state.log_alpha, gal,
                                       state.opt_alpha, cfg.lr_alpha)

    soft = lambda t, c: jax.tree_util.tree_map(  # noqa: E731
        lambda a, b: (1 - cfg.tau) * a + cfg.tau * b, t, c)
    new = state._replace(actor=actor, c1=c1, c2=c2, t1=soft(state.t1, c1),
                         t2=soft(state.t2, c2), log_alpha=log_alpha,
                         opt_actor=opt_actor, opt_c1=opt_c1, opt_c2=opt_c2,
                         opt_alpha=opt_alpha, steps=state.steps + 1)
    return new, {"critic_loss": (lc1 + lc2) / 2, "actor_loss": la,
                 "alpha": jnp.exp(log_alpha), "entropy": h_now}


# ===========================================================================
# DQN-TS baseline
# ===========================================================================


class DqnState(NamedTuple):
    q: Any
    q_target: Any
    opt: AdamState
    replay: ReplayState
    steps: jnp.ndarray


def dqn_init(key, cfg: AgentConfig, state_dim: int, action_dim: int,
             n_max: int) -> DqnState:
    q = nets.init_critic(key, state_dim, action_dim, cfg.hidden)
    return DqnState(q=q, q_target=jax.tree_util.tree_map(lambda x: x, q),
                    opt=adam_init(q),
                    replay=replay_init(cfg.replay_capacity,
                                       transition_spec(state_dim,
                                                       action_dim)),
                    steps=jnp.zeros((), jnp.int32))


def dqn_act(state: DqnState, cfg: AgentConfig, s, key,
            greedy: bool = False) -> jnp.ndarray:
    qv = nets.apply_critic(state.q, s)
    eps = cfg.eps_end + (cfg.eps_start - cfg.eps_end) * jnp.exp(
        -state.steps.astype(jnp.float32) / cfg.eps_decay_steps)
    eps = jnp.where(greedy, 0.0, eps)
    k1, k2 = jax.random.split(key)
    rand_a = jax.random.randint(k1, (), 0, qv.shape[-1])
    best = jnp.argmax(qv, axis=-1)
    return jnp.where(jax.random.uniform(k2) < eps, rand_a,
                     best).astype(jnp.int32)


def dqn_update(state: DqnState, cfg: AgentConfig, key
               ) -> Tuple[DqnState, dict]:
    batch: Transition = replay_sample(state.replay, key, cfg.batch_size)
    qn = nets.apply_critic(state.q_target, batch.s_next).max(-1)
    tgt = jax.lax.stop_gradient(batch.r + cfg.gamma * qn)

    def loss(qp):
        qa = jnp.take_along_axis(nets.apply_critic(qp, batch.s),
                                 batch.a[:, None], axis=1)[:, 0]
        return jnp.mean((qa - tgt) ** 2)

    lv, g = jax.value_and_grad(loss)(state.q)
    q, opt = adam_update(state.q, g, state.opt, cfg.lr_critic)
    soft = jax.tree_util.tree_map(
        lambda a, b: (1 - cfg.tau) * a + cfg.tau * b, state.q_target, q)
    return state._replace(q=q, q_target=soft, opt=opt,
                          steps=state.steps + 1), {"critic_loss": lv}
