"""Networks for LAD-TS and baselines (paper §IV-A, Fig. 4).

All tiny MLPs (paper Table IV: two hidden layers of 20 units) built
functionally so they vmap cleanly over the B per-ES agents.
"""
from __future__ import annotations

import math
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

TIME_EMBED_DIM = 16


def _linear_init(key, nin, nout):
    lim = 1.0 / math.sqrt(nin)
    kw, kb = jax.random.split(key)
    return {
        "w": jax.random.uniform(kw, (nin, nout), jnp.float32, -lim, lim),
        "b": jax.random.uniform(kb, (nout,), jnp.float32, -lim, lim),
    }


def init_mlp(key, dims: Sequence[int]) -> list:
    keys = jax.random.split(key, len(dims) - 1)
    return [_linear_init(k, dims[i], dims[i + 1])
            for i, k in enumerate(keys)]


def apply_mlp(params: list, x: jnp.ndarray, final_act=None) -> jnp.ndarray:
    h = x
    for i, layer in enumerate(params):
        h = h @ layer["w"] + layer["b"]
        if i + 1 < len(params):
            h = jax.nn.relu(h)
        elif final_act is not None:
            h = final_act(h)
    return h


def timestep_embed(i, dim: int = TIME_EMBED_DIM) -> jnp.ndarray:
    """Sinusoidal encoding of the denoising step index (Fig. 4)."""
    half = dim // 2
    freqs = jnp.exp(-math.log(100.0) * jnp.arange(half, dtype=jnp.float32)
                    / half)
    ang = jnp.asarray(i, jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# LADN: eps_theta(x_i, i, s)
# ---------------------------------------------------------------------------


def init_ladn(key, state_dim: int, action_dim: int,
              hidden: Tuple[int, ...] = (20, 20)) -> list:
    nin = action_dim + TIME_EMBED_DIM + state_dim
    return init_mlp(key, (nin, *hidden, action_dim))


def apply_ladn(params: list, x, i, s) -> jnp.ndarray:
    """x (..., A), i scalar (or (...,)), s (..., S) -> eps (..., A)."""
    t = timestep_embed(i)
    t = jnp.broadcast_to(t, x.shape[:-1] + (TIME_EMBED_DIM,))
    inp = jnp.concatenate([x, t, s], axis=-1)
    return apply_mlp(params, inp)


# ---------------------------------------------------------------------------
# Critic: Q(s) -> R^A (discrete-action double critic)
# ---------------------------------------------------------------------------


def init_critic(key, state_dim: int, action_dim: int,
                hidden: Tuple[int, ...] = (20, 20)) -> list:
    return init_mlp(key, (state_dim, *hidden, action_dim))


def apply_critic(params: list, s) -> jnp.ndarray:
    return apply_mlp(params, s)
