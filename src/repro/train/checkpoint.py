"""Flat-npz checkpointing for params + optimizer state + step.

No orbax in this environment; paths are joined with '/' into npz keys and
round-trip exactly (dtypes preserved, bf16 included via a view-cast shim
since npz has no native bfloat16).
"""
from __future__ import annotations

import os
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_BF16_TAG = "__bf16__"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for keypath, leaf in flat:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in keypath)
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            out[key + _BF16_TAG] = arr.view(np.uint16)
        else:
            out[key] = arr
    return out


def save_checkpoint(path: str, params, opt_state=None, step: int = 0):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    blobs = {"params/" + k: v for k, v in _flatten(params).items()}
    if opt_state is not None:
        blobs.update({"opt/" + k: v
                      for k, v in _flatten(opt_state).items()})
    blobs["__step__"] = np.asarray(step)
    tmp = path + ".tmp"
    np.savez(tmp, **blobs)
    os.replace(tmp + ".npz" if os.path.exists(tmp + ".npz") else tmp, path)


def restore_checkpoint(path: str, params_like,
                       opt_like=None) -> Tuple[Any, Any, int]:
    """Restore into the structure of ``params_like`` / ``opt_like``."""
    with np.load(path) as z:
        blobs = {k: z[k] for k in z.files}
    step = int(blobs.pop("__step__", 0))

    def rebuild(like, prefix):
        flat = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for keypath, leaf in flat[0]:
            key = prefix + "/".join(
                str(getattr(k, "key",
                            getattr(k, "idx", getattr(k, "name", k))))
                for k in keypath)
            if key + _BF16_TAG in blobs:
                arr = jnp.asarray(blobs[key + _BF16_TAG]).view(jnp.bfloat16)
            else:
                arr = jnp.asarray(blobs[key])
            assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
            leaves.append(arr)
        return jax.tree_util.tree_unflatten(flat[1], leaves)

    params = rebuild(params_like, "params/")
    opt = rebuild(opt_like, "opt/") if opt_like is not None else None
    return params, opt, step
