"""Sequence-chunked cross-entropy.

At framework scale the full logits tensor is the single biggest activation
(train_4k x 256k vocab = 0.5 TB in bf16), so the head matmul + softmax-CE
run per sequence chunk under jax.checkpoint: logits for a chunk exist only
transiently in both forward and backward.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.transformer import apply_head


def _chunk_ce(params, cfg, h_chunk, labels_chunk, mask_chunk):
    """h (B,c,d), labels (B,c)[or (B,K,c)] -> (sum_loss, sum_count)."""
    logits = apply_head(params, cfg, h_chunk).astype(jnp.float32)
    if cfg.num_codebooks:
        # logits (B,c,K,V); labels (B,K,c)
        labels_chunk = labels_chunk.swapaxes(1, 2)        # (B,c,K)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels_chunk[..., None],
                               axis=-1)[..., 0]
    nll = lse - gold
    if cfg.num_codebooks:
        nll = nll.mean(axis=-1)                            # avg codebooks
    nll = nll * mask_chunk
    return nll.sum(), mask_chunk.sum()


def chunked_ce_loss(params, cfg, hidden, labels, mask=None,
                    chunk: int = 256):
    """hidden (B,S,d); labels (B,S) or (B,K,S); mask (B,S) of 0/1."""
    B, S, d = hidden.shape
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    c = min(chunk, S)
    while S % c:
        c //= 2
    n = S // c
    hs = hidden.reshape(B, n, c, d).swapaxes(0, 1)         # (n,B,c,d)
    ms = mask.reshape(B, n, c).swapaxes(0, 1)
    if cfg.num_codebooks:
        ls = labels.reshape(B, cfg.num_codebooks, n, c).transpose(2, 0, 1, 3)
    else:
        ls = labels.reshape(B, n, c).swapaxes(0, 1)

    ckpt = jax.checkpoint(
        lambda h, l, m: _chunk_ce(params, cfg, h, l, m))

    def body(carry, xs):
        tot, cnt = carry
        h, l, m = xs
        s, k = ckpt(h, l, m)
        return (tot + s, cnt + k), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())),
                                 (hs, ls, ms))
    return tot / jnp.maximum(cnt, 1.0)
