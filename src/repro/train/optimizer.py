"""Minimal-but-real AdamW (no optax in this environment).

Functional: ``init(params) -> state``, ``update(grads, state, params) ->
(updates, state)``.  Optimizer state shards exactly like params (same tree
structure), so the dry-run's memory analysis accounts for it.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    learning_rate: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def _schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.learning_rate * warm * (cfg.min_lr_ratio
                                       + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def init(params) -> AdamWState:
    zeros = lambda t: jax.tree_util.tree_map(  # noqa: E731
        lambda p: jnp.zeros(p.shape, jnp.float32), t)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros(params),
                      nu=zeros(params))


def update(cfg: AdamWConfig, grads, state: AdamWState, params):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    if cfg.grad_clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.grad_clip_norm
                            / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
    step = state.step + 1
    lr = _schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m_new = cfg.b1 * m + (1 - cfg.b1) * g32
        v_new = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        mhat = m_new / b1c
        vhat = v_new / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), \
            m_new, v_new

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_m = jax.tree_util.tree_leaves(state.mu)
    flat_v = jax.tree_util.tree_leaves(state.nu)
    flat_p = jax.tree_util.tree_leaves(params)
    out = [upd(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v), metrics
