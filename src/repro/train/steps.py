"""Step builders: the jit-able train / prefill / decode functions.

These are the exact functions the launcher jits on the production mesh and
the dry-run lowers with ShapeDtypeStructs — one source of truth.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.launch import sharding as shlib
from repro.models import moe as moelib
from repro.models.transformer import forward
from repro.train import optimizer as opt_lib
from repro.train.losses import chunked_ce_loss


def make_train_step(cfg, opt_cfg: Optional[opt_lib.AdamWConfig] = None):
    """Microbatched (gradient-accumulation) train step.

    ``cfg.microbatches`` splits the global batch along dim 0 and scans,
    accumulating f32 grads.  This bounds the dominant training activation
    — the remat residual stack L x (B/k) x S x d — at the cost of k-fold
    smaller per-step matmuls.
    """
    opt_cfg = opt_cfg or opt_lib.AdamWConfig()

    def loss_fn(p, mb):
        out = forward(p, cfg, mb, mode="train")
        loss = chunked_ce_loss(p, cfg, out["hidden"], mb["labels"],
                               mb.get("mask"))
        return loss + out["aux"], (loss, out["aux"])

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        B = batch["tokens"].shape[0]
        k = max(1, min(cfg.microbatches, B))
        while B % k:
            k -= 1
        # keep B/k divisible by the data-parallel shard count: an uneven
        # microbatch (e.g. 256/16 = 16 rows on 32 dp shards) pads every
        # activation 2x per chip (measured on the 2x16x16 mesh, §Perf H3)
        ctx0 = shlib.current()
        if ctx0 is not None:
            dp = 1
            for ax in ("pod", "data"):
                if ax in ctx0.mesh.axis_names:
                    dp *= ctx0.mesh.shape[ax]
            while k > 1 and ((B // k) % dp or B % k):
                k -= 1
        # Hoisted MoE layout (§Perf): transform expert weights to the
        # shard-ready (M, r, d, f_lp) layout ONCE per step, differentiate
        # w.r.t. the transformed tree, and inverse-transform the grads —
        # instead of re-laying-out inside every (layer x microbatch)
        # iteration (the re-layout lowers to per-iteration collectives).
        ctx = shlib.current()
        hoist = (cfg.moe is not None and cfg.hoist_moe_layout
                 and ctx is not None and "model" in ctx.mesh.axis_names)
        M = ctx.mesh.shape["model"] if hoist else 1
        gparams = moelib.prepare_tree(params, cfg, M) if hoist else params
        if k == 1:
            (total, (loss, aux)), grads = grad_fn(gparams, batch)
        else:
            mbs = jax.tree_util.tree_map(
                lambda x: x.reshape((k, B // k) + x.shape[1:]), batch)

            def body(acc, mb):
                g_acc, tot_a, loss_a, aux_a = acc
                (tot, (loss, aux)), g = grad_fn(gparams, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, tot_a + tot, loss_a + loss, aux_a + aux), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), gparams)
            z = jnp.zeros((), jnp.float32)
            (grads, total, loss, aux), _ = jax.lax.scan(
                body, (zeros, z, z, z), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / k, grads)
            total, loss, aux = total / k, loss / k, aux / k
        if hoist:
            grads = moelib.unprepare_grads(grads, cfg, M)
        params, opt_state, metrics = opt_lib.update(opt_cfg, grads,
                                                    opt_state, params)
        metrics.update({"loss": loss, "aux_loss": aux, "total_loss": total})
        return params, opt_state, metrics

    return train_step


def _maybe_hoist(cfg, params):
    ctx = shlib.current()
    if (cfg.moe is not None and cfg.hoist_moe_layout and ctx is not None
            and "model" in ctx.mesh.axis_names):
        return moelib.prepare_tree(params, cfg, ctx.mesh.shape["model"])
    return params


def make_prefill_step(cfg, max_len: Optional[int] = None):
    def prefill_step(params, batch):
        params = _maybe_hoist(cfg, params)
        out = forward(params, cfg, batch, mode="prefill", max_len=max_len)
        return out["last_logits"], out["states"]

    return prefill_step


def make_decode_step(cfg, sample: bool = False, temperature: float = 1.0):
    def decode_step(params, batch, states, rng=None):
        params = _maybe_hoist(cfg, params)
        out = forward(params, cfg, batch, mode="decode", states=states)
        logits = out["logits"]
        if sample:
            tok = jax.random.categorical(rng, logits / temperature, axis=-1)
        else:
            tok = jnp.argmax(logits, axis=-1)
        return logits, tok.astype(jnp.int32), out["states"]

    return decode_step


def make_paged_prefill_step(cfg):
    """One prompt chunk into the shared page pool (batch 1).

    batch = {tokens (1, C), start (), block_table (W,)}; returns
    (chunk_logits (1, C, V...), new page pools).  The engine calls this
    once per chunk with a fixed C so the jit cache stays single-entry.
    """
    def paged_prefill_step(params, batch, states):
        params = _maybe_hoist(cfg, params)
        out = forward(params, cfg, batch, mode="paged_prefill",
                      states=states)
        return out["chunk_logits"], out["states"]

    return paged_prefill_step


def make_paged_decode_step(cfg, sample: bool = False,
                           temperature: float = 1.0):
    """One decode token per lane over the shared page pool.

    batch = {tokens (B, 1), block_tables (B, W), lengths (B,)}; inactive
    lanes carry all-null tables and length 0, and their tokens are
    ignored by the engine.
    """
    def paged_decode_step(params, batch, states, rng=None):
        params = _maybe_hoist(cfg, params)
        out = forward(params, cfg, batch, mode="paged_decode", states=states)
        logits = out["logits"]
        if sample:
            tok = jax.random.categorical(rng, logits / temperature, axis=-1)
        else:
            tok = jnp.argmax(logits, axis=-1)
        return logits, tok.astype(jnp.int32), out["states"]

    return paged_decode_step


def make_eval_step(cfg):
    """Forward-only loss (validation)."""
    def eval_step(params, batch):
        out = forward(params, cfg, batch, mode="train")
        return chunked_ce_loss(params, cfg, out["hidden"], batch["labels"],
                               batch.get("mask"))

    return eval_step
