"""ShapeDtypeStruct input specs for every (architecture x input shape).

``input_specs`` returns everything a step function consumes *except*
params/optimizer state, as weak-type-correct, shardable ShapeDtypeStructs —
no device allocation, following the shannon/kernels dry-run pattern.

Modality carve-outs: [audio] provides the EnCodec codebook token streams;
[vlm] provides precomputed ViT patch embeddings (the one sanctioned stub).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape
from repro.launch import sharding as shlib
from repro.models.transformer import init_layer_states


def _sds(shape, dtype, mesh: Optional[Mesh], spec: Optional[P]):
    if mesh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _batch_axes(mesh: Optional[Mesh], global_batch: Optional[int] = None):
    """Batch sharding axes, degrading gracefully when the batch is too
    small to split (long_500k has global_batch=1: replicate)."""
    if mesh is None:
        return "data"
    axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    if global_batch is not None:
        size = 1
        kept = []
        for a in axes:
            size *= mesh.shape[a]
        if global_batch % size != 0:
            kept = [a for a in axes if global_batch % mesh.shape[a] == 0]
            axes = tuple(kept[:1])  # fall back to one axis or none
            if not axes or global_batch % mesh.shape[axes[0]] != 0:
                return None
    return axes if len(axes) > 1 else (axes[0] if axes else None)


def token_specs(cfg: ModelConfig, shape: InputShape,
                mesh: Optional[Mesh] = None) -> Dict[str, Any]:
    """Model inputs (tokens / patches / labels) for the given step kind."""
    B = shape.global_batch
    dp = _batch_axes(mesh, B)
    out: Dict[str, Any] = {}
    if shape.kind == "decode":
        S = 1
    else:
        S = shape.seq_len
    text_len = S
    if cfg.vision_patches and shape.kind != "decode":
        text_len = S - cfg.vision_patches
        assert text_len > 0
        out["patches"] = _sds((B, cfg.vision_patches, cfg.vision_dim),
                              jnp.dtype(cfg.dtype), mesh, P(dp, None, None))
    if cfg.num_codebooks:
        out["tokens"] = _sds((B, cfg.num_codebooks, text_len), jnp.int32,
                             mesh, P(dp, None, None))
    else:
        out["tokens"] = _sds((B, text_len), jnp.int32, mesh, P(dp, None))
    if shape.kind == "train":
        if cfg.num_codebooks:
            out["labels"] = _sds((B, cfg.num_codebooks, S), jnp.int32, mesh,
                                 P(dp, None, None))
        else:
            out["labels"] = _sds((B, S), jnp.int32, mesh, P(dp, None))
        if cfg.vision_patches:
            out["mask"] = _sds((B, S), jnp.float32, mesh, P(dp, None))
    return out


def state_specs(cfg: ModelConfig, shape: InputShape,
                mesh: Optional[Mesh] = None):
    """Decode-shape layer states: a seq_len-deep cache, as SDS."""
    assert shape.kind == "decode"
    states = init_layer_states(cfg, shape.global_batch, shape.seq_len,
                               make=jax.ShapeDtypeStruct)
    if mesh is None:
        return states
    specs = shlib.state_pspecs(states, mesh,
                               batch_axes=_batch_axes(mesh,
                                                      shape.global_batch))
    return jax.tree_util.tree_map(
        lambda s, sp: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
        states, specs)


def param_specs(cfg: ModelConfig, mesh: Optional[Mesh] = None):
    from repro.models.transformer import abstract_params
    params = abstract_params(cfg)
    if mesh is None:
        return params
    specs = shlib.param_pspecs(params, mesh)
    return jax.tree_util.tree_map(
        lambda s, sp: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
        params, specs)


def opt_state_specs(param_sds):
    """AdamW state mirrors params twice in f32 (mu, nu) + a step counter."""
    from repro.train.optimizer import AdamWState
    f32 = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32,
                                       sharding=getattr(s, "sharding", None)),
        param_sds)
    return AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32), mu=f32,
                      nu=f32)


def output_shardings(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                     out_shapes) -> object:
    """Explicit out_shardings for the step functions.

    Inferred output shardings can be invalid when a dim is smaller than the
    mesh axis GSPMD picks for it (e.g. an 8-kv-head cache on 16-way
    'model'), so the launcher always pins outputs.
    """
    dp = _batch_axes(mesh, shape.global_batch)

    def ns(spec):
        return NamedSharding(mesh, spec)

    def logits_spec(x):
        if x.ndim == 3:    # (B, K, V) multi-codebook
            return ns(P(dp, None, "model"))
        return ns(P(dp, "model"))

    if shape.kind == "train":
        params_sd, opt_sd, metrics_sd = out_shapes
        pspec = jax.tree_util.tree_map(
            lambda s: ns(s), shlib.param_pspecs(params_sd, mesh),
            is_leaf=lambda x: isinstance(x, P))
        from repro.train.optimizer import AdamWState
        opt = AdamWState(
            step=ns(P()),
            mu=jax.tree_util.tree_map(
                lambda s: ns(s), shlib.param_pspecs(opt_sd.mu, mesh),
                is_leaf=lambda x: isinstance(x, P)),
            nu=jax.tree_util.tree_map(
                lambda s: ns(s), shlib.param_pspecs(opt_sd.nu, mesh),
                is_leaf=lambda x: isinstance(x, P)))
        metrics = jax.tree_util.tree_map(lambda s: ns(P()), metrics_sd)
        return (pspec, opt, metrics)

    def states_shardings(states_sd):
        specs = shlib.state_pspecs(states_sd, mesh, batch_axes=dp)
        return jax.tree_util.tree_map(
            lambda sp: ns(sp), specs, is_leaf=lambda x: isinstance(x, P))

    if shape.kind == "prefill":
        last_logits_sd, states_sd = out_shapes
        return (logits_spec(last_logits_sd), states_shardings(states_sd))
    logits_sd, tok_sd, states_sd = out_shapes
    tok = ns(P(dp, None)) if tok_sd.ndim == 2 else ns(P(dp))
    return (logits_spec(logits_sd), tok, states_shardings(states_sd))


def input_specs(cfg: ModelConfig, shape: InputShape,
                mesh: Optional[Mesh] = None) -> Tuple[tuple, dict]:
    """(args, kwargs) for the shape's step function, params included."""
    from repro.models.transformer import config_for_shape
    cfg = config_for_shape(cfg, shape)
    p = param_specs(cfg, mesh)
    toks = token_specs(cfg, shape, mesh)
    if shape.kind == "train":
        return (p, opt_state_specs(p), toks), {}
    if shape.kind == "prefill":
        return (p, toks), {}
    return (p, toks, state_specs(cfg, shape, mesh)), {}
