"""Logical-axis sharding for the serving/training substrate.

Models annotate activations with *logical* axis names ("batch", "heads",
"ff", "kv_seq", ...).  A :class:`ShardingContext` maps logical names to mesh
axes and applies ``with_sharding_constraint``; outside a context (CPU smoke
tests) the annotations are no-ops, so the same model code runs everywhere.

Parameter sharding is path-based (:func:`param_pspecs`) — rules keyed on the
parameter's leaf name, MaxText-style, so new blocks get sensible default
sharding without touching the launcher.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

# default logical-axis -> mesh-axis rules (single-pod mesh ('data','model'))
DEFAULT_RULES: Dict[str, Optional[object]] = {
    "batch": "data",        # replaced by ('pod','data') on the multi-pod mesh
    "seq": None,            # sequence usually replicated...
    "kv_seq": "model",      # ...but decode KV caches shard sequence on model
    "heads": "model",
    "kv_heads": None,       # kv heads can be tiny (MQA kv=1): replicate
    "ff": "model",
    "expert": "model",
    "vocab": "model",
    "embed": None,
    "hidden": None,
    "rec": "model",         # recurrent width (RG-LRU / xLSTM projections)
}


class ShardingContext:
    def __init__(self, mesh: Mesh, rules: Optional[Dict[str, object]] = None):
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES)
        if rules:
            self.rules.update(rules)
        # on a multi-pod mesh, "batch" spans both pod and data axes
        if "pod" in mesh.axis_names and self.rules.get("batch") == "data":
            self.rules["batch"] = ("pod", "data")

    def spec(self, *logical: Optional[str]) -> P:
        axes = []
        for name in logical:
            axes.append(None if name is None else self.rules.get(name))
        return P(*axes)

    def sharding(self, *logical: Optional[str]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(*logical))


def current() -> Optional[ShardingContext]:
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def use(ctx: Optional[ShardingContext]):
    prev = getattr(_state, "ctx", None)
    _state.ctx = ctx
    try:
        yield ctx
    finally:
        _state.ctx = prev


def act(x, *logical: Optional[str]):
    """Constrain an activation's sharding by logical axis names (no-op when
    no context is active, e.g. in CPU smoke tests)."""
    ctx = current()
    if ctx is None or x.ndim != len(logical):
        return x
    return jax.lax.with_sharding_constraint(x, ctx.sharding(*logical))


# ---------------------------------------------------------------------------
# parameter sharding rules (by leaf name)
# ---------------------------------------------------------------------------

# leaf-name -> which dim (negative ok) gets the 'model' axis.  Everything
# else is replicated.  Dims are relative to the *unstacked* param; a leading
# scan-layer axis is detected by path prefix and skipped.
_COL_SHARDED = {  # shard output dim (last)
    "wq", "wk", "wv", "w_up", "w_gate", "w_in", "w_z", "head",
    "w_gate_in", "w_proj",
}
_ROW_SHARDED = {  # shard input dim (first of the matmul = -2)
    "wo", "w_down", "w_out",
}
_EXPERT_SHARDED = {  # MoE stacked expert weights: shard expert dim (dim 0)
    "we_up", "we_gate", "we_down",
}
_VOCAB_SHARDED = {"embed"}  # (V, d) or (K, V, d): shard the V dim


def _leaf_spec(path: Tuple[str, ...], shape: Tuple[int, ...],
               axis_sizes: Optional[Dict[str, int]] = None) -> P:
    """2D weight sharding: tensor-parallel on 'model' plus FSDP on 'data'.

    The 132B-scale archs do not fit at 16-way TP alone (16.5 GB/chip of
    bf16 weights + 4x that in f32 optimizer state), so every matrix also
    shards its other dim over 'data' (ZeRO-3 style; GSPMD inserts the
    per-layer all-gathers).  Pods replicate weights (pure DP across pods).

    Every assignment is divisibility-guarded against the mesh axis sizes
    (NamedSharding on concrete arrays forbids uneven partitions — e.g.
    Mixtral's 8 experts on a 16-way 'model' axis fall back to sharding
    d_ff instead).
    """
    name = path[-1]
    stacked = "scan" in path  # scan-over-layers stacked leading axis
    off = 1 if stacked else 0
    spec = [None] * len(shape)

    def put(dim: int, axis: str) -> bool:
        if spec[dim % len(shape)] is not None:
            return False
        if axis_sizes is not None:
            size = axis_sizes.get(axis, 1)
            if size > 1 and shape[dim % len(shape)] % size != 0:
                return False
        spec[dim % len(shape)] = axis
        return True

    if name in _COL_SHARDED and len(shape) - off >= 2:
        put(-1, "model")
        put(-2, "data")
    elif name in _ROW_SHARDED and len(shape) - off >= 2:
        put(-2, "model")
        put(-1, "data")
    elif name in _EXPERT_SHARDED:
        # prefer expert-parallel; fall back to d_ff tensor parallel
        if not put(off, "model"):
            put(-1 if name in ("we_up", "we_gate") else -2, "model")
        # FSDP dim: d for we_up/we_gate; whichever of (f, d) is free for
        # we_down (both must stay sharded or a 132B-scale expert stack
        # leaves multi-GB per chip — caught by the dry-run memory check)
        if not put(off + 1, "data"):
            put(-1, "data")
    elif name in _VOCAB_SHARDED:
        # (V, d) or (K, V, d) for multi-codebook embeds: V is always dim -2
        put(-2, "model")
        put(-1, "data")
    # biases, norm scales, routers, lru params: replicated
    return P(*spec)


def param_pspecs(params, mesh: Optional[Mesh] = None) -> object:
    """PartitionSpec pytree matching ``params`` (path-based rules)."""
    axis_sizes = dict(mesh.shape) if mesh is not None else None
    flat = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for keypath, leaf in flat[0]:
        path = tuple(
            getattr(k, "key", getattr(k, "name", str(k))) for k in keypath)
        path = tuple(str(p) for p in path)
        specs.append(_leaf_spec(path, leaf.shape, axis_sizes))
    return jax.tree_util.tree_unflatten(flat[1], specs)


def param_shardings(mesh: Mesh, params) -> object:
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec), param_pspecs(params, mesh),
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# layer-state (KV cache / recurrent state) sharding rules
# ---------------------------------------------------------------------------


def _state_leaf_spec(path: Tuple[str, ...], shape: Tuple[int, ...],
                     batch_axes,
                     axis_sizes: Optional[Dict[str, int]] = None) -> P:
    name = path[-1]
    stacked = "scan" in path
    off = 1 if stacked else 0
    nd = len(shape) - off
    spec = [None] * len(shape)
    if name == "pos" or nd == 0:
        return P(*spec)

    def put(dim: int, axes) -> None:
        # divisibility guard: NamedSharding on concrete arrays forbids
        # uneven partitions, so a dim the mesh axis doesn't divide falls
        # back to replication (e.g. a 6-lane dense pool on 4-way 'data')
        if axis_sizes is not None:
            size = 1
            for a in (axes if isinstance(axes, tuple) else (axes,)):
                size *= axis_sizes.get(a, 1)
            if size > 1 and shape[dim] % size != 0:
                return
        spec[dim] = axes

    put(off, batch_axes)  # leading real dim is always batch
    if name in ("k", "v", "k_scale", "v_scale") and nd == 4:
        put(off + 2, "model")         # KV cache: shard the sequence dim
    elif name == "C" and nd == 4:
        put(off + 2, "model")         # mLSTM matrix memory: shard head_dim
    elif name == "n" and nd == 3:
        put(off + 2, "model")
    # (B, d)-shaped scalars (slstm c/n/h/m, rglru h) and conv buffers:
    # batch-sharded only.
    return P(*spec)


def state_pspecs(states, mesh: Optional[Mesh] = None,
                 batch_axes="__auto__") -> object:
    if batch_axes == "__auto__":
        batch_axes = ("pod", "data") if (mesh is not None and
                                         "pod" in mesh.axis_names) else "data"
    axis_sizes = dict(mesh.shape) if mesh is not None else None
    flat = jax.tree_util.tree_flatten_with_path(states)
    specs = []
    for keypath, leaf in flat[0]:
        path = tuple(
            str(getattr(k, "key", getattr(k, "name", str(k))))
            for k in keypath)
        specs.append(_state_leaf_spec(path, leaf.shape, batch_axes,
                                      axis_sizes))
    return jax.tree_util.tree_unflatten(flat[1], specs)


def state_shardings(mesh: Mesh, states) -> object:
    """NamedSharding pytree for KV / recurrent serving state on ``mesh``
    (divisibility-guarded: indivisible dims replicate)."""
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec),
        state_pspecs(states, mesh),
        is_leaf=lambda x: isinstance(x, P))
