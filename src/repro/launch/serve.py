"""Serving launcher: one DEdgeAI-style worker on a reduced model.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b \
      --requests 8 --tokens 16
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models.transformer import init_params
from repro.serving.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--sample", action="store_true")
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    key = jax.random.key(0)
    params = init_params(key, cfg)
    engine = ServeEngine(cfg, params,
                         max_len=args.prompt_len + args.tokens
                         + cfg.vision_patches,
                         sample=args.sample)

    for r in range(args.requests):
        key, kp = jax.random.split(key)
        if cfg.num_codebooks:
            prompt = jax.random.randint(
                kp, (1, cfg.num_codebooks, args.prompt_len), 0,
                cfg.vocab_size)
        else:
            prompt = jax.random.randint(kp, (1, args.prompt_len), 0,
                                        cfg.vocab_size)
        patches = None
        if cfg.vision_patches:
            patches = jax.random.normal(
                kp, (1, cfg.vision_patches, cfg.vision_dim))
        res = engine.generate(prompt, args.tokens, rng=kp, patches=patches)
        print(f"[serve] req {r}: prefill={res.prefill_s*1e3:.1f}ms "
              f"decode={res.decode_s*1e3:.1f}ms "
              f"queue={res.queue_s*1e3:.1f}ms "
              f"tok/s={args.tokens/max(res.decode_s,1e-9):.1f}")


if __name__ == "__main__":
    main()
