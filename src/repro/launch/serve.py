"""Serving launcher: a DEdgeAI-style edge cluster on reduced models.

Replays a Poisson arrival trace through N continuous-batching engines,
with a pluggable scheduler placing each request:

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b \
      --edges 2 --scheduler jsq --requests 8 --tokens 16 --rate 4

``--scheduler lad-ts`` first trains the paper policy in the
``repro.core.env`` simulator (matching the engine count), then serves
with it — the closed loop of paper Fig. 10.

``--qos`` switches on the heterogeneous-QoS workload layer
(``repro.workload``): the trace mixes interactive / standard / batch
service classes, engines drain in priority/EDF order, learned policies
train on the extended observation (deadline slack + per-engine
affinity), ``--scheduler deadline`` becomes available, and the summary
adds deadline-miss rate and priority-weighted goodput.

``--chaos`` switches on the fault layer (``repro.faults``): a
deterministic-per-``--fault-seed`` schedule crashes / stalls / slows
engines mid-trace, orphaned requests are retried with backoff, learned
policies train against the fault-enabled simulator (availability
observation + wrong-choice penalty), ``--scheduler failure-aware``
masks DOWN engines, and the summary adds the terminal-status breakdown
(completed / failed / abandoned, retries, orphan-recovery latency).
"""
from __future__ import annotations

import argparse

import jax

from repro.cluster import (EdgeCluster, PolicyScheduler, make_scheduler,
                           poisson_trace, summarize)
from repro.cluster.schedulers import BASELINES
from repro.configs import get_config, reduced
from repro.core.agents import AgentConfig
from repro.core.diffusion import DiffusionPolicyConfig
from repro.core.env import EnvParams
from repro.core.trainer import LEARNED, train_method
from repro.faults import FaultInjector, FaultParams, FaultSpec, RetryPolicy
from repro.serving.builders import build_engines, warmup
from repro.workload import DEFAULT_MIX


def build_scheduler(name: str, n_edge: int, train_episodes: int, seed: int,
                    qos: bool = False, chaos: bool = False):
    if name == "deadline" and not qos:
        raise SystemExit("--scheduler deadline needs the QoS-extended "
                         "observation; pass --qos")
    if name == "failure-aware":
        return make_scheduler(name, n_edge, qos=qos)
    if name == "prefix-affinity":
        return make_scheduler(name, n_edge, qos=qos, fault=chaos)
    if name in BASELINES:
        return make_scheduler(name, n_edge)
    if name not in LEARNED:
        raise SystemExit(f"unknown scheduler {name!r}; options: "
                         f"{', '.join(BASELINES + LEARNED)}")
    p = EnvParams(num_bs=n_edge, num_slots=8, max_tasks=6,
                  qos_mix=DEFAULT_MIX if qos else (),
                  fault=FaultParams() if chaos else None)
    acfg = AgentConfig(train_after=40, replay_capacity=200,
                       diffusion=DiffusionPolicyConfig(num_steps=3))
    print(f"[serve] training {name} in-sim for {train_episodes} episodes "
          f"({n_edge} edge servers"
          f"{', fault-enabled' if chaos else ''})...")
    _, states = train_method(name, p, acfg, episodes=train_episodes,
                             key=jax.random.key(seed))
    return PolicyScheduler(name, acfg, states, num_engines=n_edge,
                           n_max=p.max_tasks)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--edges", type=int, default=2)
    ap.add_argument("--scheduler", default="jsq",
                    help="jsq | round-robin | random | local | deadline | "
                         "prefix-affinity | failure-aware | "
                         "lad-ts | d2sac-ts | sac-ts | dqn-ts")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--rate", type=float, default=4.0,
                    help="Poisson arrival rate (req/s)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--kv-slots", type=int, default=4)
    ap.add_argument("--train-episodes", type=int, default=3)
    ap.add_argument("--qos", action="store_true",
                    help="mixed interactive/standard/batch QoS trace + "
                         "extended scheduler observation")
    ap.add_argument("--chaos", action="store_true",
                    help="inject a deterministic fault schedule (one "
                         "crash + one slowdown) and retry orphans")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed for the --chaos fault schedule")
    ap.add_argument("--prefix-len", type=int, default=0,
                    help="share a seeded system-prompt prefix of this many "
                         "tokens across --prefix-frac of the trace (paged "
                         "engines serve repeats from the prefix cache)")
    ap.add_argument("--prefix-frac", type=float, default=0.75)
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable the paged engines' prefix cache")
    ap.add_argument("--sample", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    max_tokens = (max(args.tokens,
                      *(c.z_range[1] for c, _ in DEFAULT_MIX))
                  if args.qos else args.tokens)
    engines = build_engines(args.arch, args.edges,
                            args.prompt_len + max_tokens
                            + reduced(get_config(args.arch)).vision_patches,
                            kv_slots=args.kv_slots, sample=args.sample,
                            prefix_cache=(False if args.no_prefix_cache
                                          else None))
    cfg0 = engines[0].cfg
    vocab = cfg0.vocab_size
    warmup(engines, args.prompt_len)       # compile before timed serving

    scheduler = build_scheduler(args.scheduler, args.edges,
                                args.train_episodes, args.seed,
                                qos=args.qos, chaos=args.chaos)
    injector = retry = None
    if args.chaos:
        # horizon = expected trace span + service tail headroom
        horizon = args.requests / max(args.rate, 1e-9) + 2.0
        injector = FaultInjector.from_spec(
            FaultSpec(crashes=1, slowdowns=1), args.edges,
            horizon_s=horizon, seed=args.fault_seed)
        retry = RetryPolicy()
        for ev in injector.describe():
            print(f"[serve] fault @{ev['t_s']:.2f}s engine={ev['engine']} "
                  f"{ev['kind']}")
    cluster = EdgeCluster(engines, scheduler, seed=args.seed,
                          qos_obs=args.qos, faults=injector, retry=retry)
    trace = poisson_trace(args.requests, rate=args.rate,
                          prompt_len=args.prompt_len,
                          max_new_tokens=args.tokens, vocab_size=vocab,
                          num_origins=args.edges, seed=args.seed,
                          num_codebooks=cfg0.num_codebooks,
                          qos_mix=DEFAULT_MIX if args.qos else None,
                          prefix_len=args.prefix_len,
                          prefix_frac=args.prefix_frac)
    if cfg0.vision_patches:
        for r in trace:
            r.patches = jax.random.normal(
                jax.random.fold_in(jax.random.key(args.seed), r.rid),
                (1, cfg0.vision_patches, cfg0.vision_dim))
    done = cluster.run(trace)
    for r in sorted(done, key=lambda r: r.rid):
        if not r.done:          # failed / abandoned: no timestamps
            print(f"[serve] req {r.rid}: {r.status} ({r.fail_reason})")
            continue
        tps = (f"tok/s={len(r.tokens)/r.decode_s:.1f}"
               if r.decode_s > 0 else "tok/s=n/a")
        retried = f" attempts={r.attempts}" if r.attempts > 1 else ""
        print(f"[serve] req {r.rid}: engine={r.engine_id} "
              f"queue={r.queue_s*1e3:.1f}ms "
              f"prefill={r.prefill_s*1e3:.1f}ms "
              f"decode={r.decode_s*1e3:.1f}ms "
              f"service={r.service_s*1e3:.1f}ms {tps}{retried}")
    st = summarize(done)
    line = (f"[serve] {scheduler.name}: n={st['count']} "
            f"mean={st['mean_s']*1e3:.1f}ms p95={st['p95_s']*1e3:.1f}ms "
            f"max={st['max_s']*1e3:.1f}ms")
    if st["prefill_tokens_saved"]:
        line += (f" prefix_saved={st['prefill_tokens_saved']}tok"
                 f" hit={st['prefix_hit_rate']:.2f}")
    if args.chaos:
        fs = cluster.fault_stats
        line += (f" cr={st['completion_rate']:.3f}"
                 f" retries={st['retries']}"
                 f" failed={st['failed']} abandoned={st['abandoned']}"
                 f" orphans={fs['orphaned']}")
    if args.qos:
        line += (f" miss={st['deadline_miss_rate']:.2f}"
                 f" goodput={st['weighted_goodput']:.2f}")
        for name, cs in st.get("classes", {}).items():
            print(f"[serve]   class {name}: n={cs['count']} "
                  f"p50={cs['p50_s']*1e3:.1f}ms "
                  f"p95={cs['p95_s']*1e3:.1f}ms "
                  f"miss={cs['deadline_miss_rate']:.2f}")
    print(line)


if __name__ == "__main__":
    main()
