import os
os.environ["XLA_FLAGS"] = os.environ.get("_REPRO_EXTRA_XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"  # noqa: E501

# --- everything below may import jax ---------------------------------------
"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input shape x mesh), jit the real step function
with production in_shardings, ``.lower().compile()`` it against
ShapeDtypeStruct inputs (no allocation), and record:
  * memory_analysis()  -> bytes per device (proves it fits 16 GB HBM)
  * cost_analysis()    -> FLOPs / bytes (roofline inputs)
  * collective bytes parsed from the optimized HLO (roofline collective
    term), with while-loop trip-count scaling.

Usage:
  python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.jsonl]
"""
import argparse   # noqa: E402
import json       # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402

import jax        # noqa: E402

from repro.configs import ARCH_IDS, get_config, get_shape, SHAPES  # noqa: E402
from repro.launch import sharding as shlib                          # noqa: E402
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,      # noqa: E402
                               make_production_mesh, mesh_chips)
from repro.launch.specs import (input_specs, output_shardings,      # noqa: E402
                                _batch_axes)
from repro.models.transformer import config_for_shape               # noqa: E402
from repro.roofline import analysis as ra                           # noqa: E402
from repro.train.steps import (make_decode_step, make_prefill_step,  # noqa: E402
                               make_train_step)


def step_for_shape(cfg, shape):
    if shape.kind == "train":
        return make_train_step(cfg)
    if shape.kind == "prefill":
        return make_prefill_step(cfg)
    # decode: greedy token, no rng arg
    fn = make_decode_step(cfg, sample=False)
    return lambda params, batch, states: fn(params, batch, states)


def lower_one(arch: str, shape_name: str, multi_pod: bool,
              arch_overrides=None):
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    cfg = config_for_shape(cfg, shape)
    if arch_overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **arch_overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = shlib.ShardingContext(
        mesh, rules={"batch": _batch_axes(mesh, shape.global_batch)})
    step = step_for_shape(cfg, shape)
    args, kwargs = input_specs(cfg, shape, mesh)
    with mesh:
        with shlib.use(ctx):
            out_shapes = jax.eval_shape(step, *args, **kwargs)
            outs = output_shardings(cfg, shape, mesh, out_shapes)
            lowered = jax.jit(step, out_shardings=outs).lower(*args,
                                                              **kwargs)
    return cfg, shape, mesh, lowered


def analyse(cfg, shape, mesh, lowered, compile_s: float, compiled,
            save_hlo_dir=None):
    chips = mesh_chips(mesh)
    cost = {}
    try:
        cost = compiled.cost_analysis() or {}
    except Exception as e:  # pragma: no cover
        cost = {"error": str(e)}
    mem = {}
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes"):
                mem[k] = getattr(ma, k, None)
    except Exception as e:  # pragma: no cover
        mem = {"error": str(e)}

    hlo = compiled.as_text()
    if save_hlo_dir:
        import gzip
        os.makedirs(save_hlo_dir, exist_ok=True)
        mesh_tag = "x".join(str(s) for s in mesh.devices.shape)
        path = os.path.join(save_hlo_dir,
                            f"{cfg.name}__{shape.name}__{mesh_tag}.hlo.gz")
        with gzip.open(path, "wt") as f:
            f.write(hlo)
    colls = ra.collect_collectives(hlo)
    coll_bytes = sum(c.scaled_bytes for c in colls)
    coll_by_kind = {}
    for c in colls:
        coll_by_kind[c.kind] = coll_by_kind.get(c.kind, 0) + c.scaled_bytes

    raw_flops = float(cost.get("flops", 0.0) or 0.0)
    raw_bytes = float(cost.get("bytes accessed", 0.0) or 0.0)
    flops_scaled, bytes_scaled, dot_flops = ra.scaled_cost(
        hlo, raw_flops, raw_bytes)
    # prefer the trip-scaled dot-walk estimate when it exceeds the raw
    # number (raw counts loop bodies once); keep raw otherwise.
    hlo_flops = max(flops_scaled, raw_flops)
    hlo_bytes = max(bytes_scaled, raw_bytes)
    mflops = ra.model_flops(cfg, shape)
    mflops_per_chip = mflops / chips
    terms = ra.roofline_terms(hlo_flops, hlo_bytes, coll_bytes, chips,
                              PEAK_FLOPS_BF16, HBM_BW, ICI_BW)
    return {
        "arch": cfg.name,
        "shape": shape.name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "chips": chips,
        "compile_s": round(compile_s, 1),
        "raw_flops": raw_flops,
        "hlo_flops": hlo_flops,
        "hlo_bytes": hlo_bytes,
        "collective_bytes": coll_bytes,
        "collective_by_kind": coll_by_kind,
        "n_collectives": len(colls),
        "model_flops": mflops,
        "useful_ratio": ((mflops_per_chip / hlo_flops)
                         if hlo_flops else None),
        "memory_analysis": mem,
        **terms,
    }


def run_one(arch: str, shape_name: str, multi_pod: bool, verbose=True,
            arch_overrides=None, tag=None, save_hlo_dir=None):
    t0 = time.time()
    cfg, shape, mesh, lowered = lower_one(arch, shape_name, multi_pod,
                                          arch_overrides=arch_overrides)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} mesh="
              f"{'x'.join(str(s) for s in mesh.devices.shape)} "
              f"lower={t1-t0:.1f}s compile={t2-t1:.1f}s", flush=True)
        try:
            print(compiled.memory_analysis())
        except Exception as e:
            print("memory_analysis unavailable:", e)
        try:
            ca = compiled.cost_analysis()
            print({k: ca[k] for k in ("flops", "bytes accessed")
                   if k in ca})
        except Exception as e:
            print("cost_analysis unavailable:", e)
    rec = analyse(cfg, shape, mesh, lowered, t2 - t1, compiled,
                  save_hlo_dir=save_hlo_dir)
    if tag:
        rec["tag"] = tag
    if arch_overrides:
        rec["overrides"] = {k: str(v) for k, v in arch_overrides.items()}
    return rec


def skip_reason(arch: str, shape_name: str):
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    if shape.kind == "decode" and shape.seq_len > 65536:
        if not cfg.is_subquadratic():
            return "full attention without long-context variant"
    return None


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCH_IDS)
    ap.add_argument("--shape", default=None,
                    choices=[s.name for s in SHAPES])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) for the chosen mesh")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--tag", default=None,
                    help="label for this run's records (perf iterations)")
    ap.add_argument("--save-hlo", default=None,
                    help="directory to store gzipped optimized HLO per "
                         "combo (lets roofline analysis be re-run without "
                         "recompiling)")
    ap.add_argument("--set", dest="overrides", action="append", default=[],
                    help="ModelConfig override, e.g. --set microbatches=4 "
                         "--set remat=False (perf iterations)")
    args = ap.parse_args()

    overrides = {}
    for item in args.overrides:
        k, v = item.split("=", 1)
        try:
            import ast
            overrides[k] = ast.literal_eval(v)
        except (ValueError, SyntaxError):
            overrides[k] = v

    combos = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                combos.append((a, s.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos = [(args.arch, args.shape)]

    done = set()
    if args.out and args.skip_existing and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    done.add((r["arch"], r["shape"], r["mesh"]))
                except Exception:
                    pass

    mesh_tag = "2x16x16" if args.multi_pod else "16x16"
    failures = []
    for arch, shape_name in combos:
        if (arch, shape_name, mesh_tag) in done:
            print(f"[dryrun] skip existing {arch} x {shape_name}")
            continue
        reason = skip_reason(arch, shape_name)
        rec = None
        if reason:
            rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
                   "skipped": reason}
            print(f"[dryrun] SKIP {arch} x {shape_name}: {reason}")
        else:
            try:
                rec = run_one(arch, shape_name, args.multi_pod,
                              arch_overrides=overrides or None,
                              tag=args.tag, save_hlo_dir=args.save_hlo)
                print(f"[dryrun] OK {arch} x {shape_name}: "
                      f"bottleneck={rec['bottleneck']} "
                      f"compute={rec['compute_s']:.3e}s "
                      f"memory={rec['memory_s']:.3e}s "
                      f"collective={rec['collective_s']:.3e}s", flush=True)
            except Exception as e:
                traceback.print_exc()
                failures.append((arch, shape_name, str(e)))
                rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
                       "error": str(e)[:2000]}
        if args.out and rec is not None:
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")
    if failures:
        print(f"[dryrun] {len(failures)} FAILURES:")
        for a, s, e in failures:
            print(f"  {a} x {s}: {e[:200]}")
        raise SystemExit(1)
    print("[dryrun] all combinations lowered and compiled successfully")


if __name__ == "__main__":
    main()
