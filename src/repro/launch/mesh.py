"""Production mesh definitions (TPU v5e target).

Single pod : (16, 16)    -> ("data", "model"), 256 chips
Multi-pod  : (2, 16, 16) -> ("pod", "data", "model"), 512 chips

Functions (not module constants) so importing never touches jax device
state — the dry-run must set XLA_FLAGS before the first jax call.
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12        # per chip, FLOP/s
HBM_BW = 819e9                  # per chip, bytes/s
ICI_BW = 50e9                   # per link, bytes/s


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh():
    """1-device mesh with the production axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def mesh_chips(mesh) -> int:
    return mesh.devices.size
