"""Production mesh definitions (TPU v5e target).

Single pod : (16, 16)    -> ("data", "model"), 256 chips
Multi-pod  : (2, 16, 16) -> ("pod", "data", "model"), 512 chips

Functions (not module constants) so importing never touches jax device
state — the dry-run must set XLA_FLAGS before the first jax call.
"""
from __future__ import annotations

import math

import jax

# TPU v5e hardware constants used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12        # per chip, FLOP/s
HBM_BW = 819e9                  # per chip, bytes/s
ICI_BW = 50e9                   # per link, bytes/s


def make_production_mesh(*, multi_pod: bool = False, shape=None, axes=None):
    """Production mesh, guarded against the runtime's device count.

    ``jax.make_mesh`` consumes ALL visible devices, so a mismatched
    device count surfaces as an opaque reshape error deep in jax; fail
    early instead, naming the device count, so a CPU box asking for the
    256-chip pod gets a clear message (use :func:`make_smoke_mesh`
    there).  ``shape``/``axes`` override the default single/multi-pod
    topologies together."""
    if (shape is None) != (axes is None):
        raise ValueError("pass shape and axes together (or neither)")
    if shape is None:
        shape = (2, 16, 16) if multi_pod else (16, 16)
        axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    have = jax.device_count()
    if have != need:
        raise ValueError(
            f"mesh shape {tuple(shape)} ({' x '.join(map(str, shape))} = "
            f"{need} chips) does not factor into this runtime's "
            f"{have} device(s); run on a {need}-chip slice or use "
            f"make_smoke_mesh() for single-device smoke tests")
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_smoke_mesh():
    """1-device mesh with the production axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def mesh_chips(mesh) -> int:
    return mesh.devices.size
