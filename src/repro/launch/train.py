"""Training launcher.

CPU (this environment): reduced configs, real optimization, loss curve.
TPU: the same code path jits onto the production mesh with the dry-run's
shardings (``--mesh single|multi``).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b \
      --smoke --steps 50
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.data.pipeline import DataConfig, synth_batch
from repro.launch import sharding as shlib
from repro.launch.mesh import make_production_mesh, make_smoke_mesh
from repro.models.transformer import init_params
from repro.train import optimizer as opt_lib
from repro.train.checkpoint import save_checkpoint
from repro.train.steps import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--mesh", choices=["none", "single", "multi"],
                    default="none")
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    if cfg.vision_patches and args.seq_len <= cfg.vision_patches:
        args.seq_len = cfg.vision_patches + 64

    opt_cfg = opt_lib.AdamWConfig(learning_rate=args.lr,
                                  warmup_steps=max(args.steps // 10, 1),
                                  total_steps=args.steps)
    dc = DataConfig(batch=args.batch, seq_len=args.seq_len)

    mesh = None
    if args.mesh == "single":
        mesh = make_production_mesh()
    elif args.mesh == "multi":
        mesh = make_production_mesh(multi_pod=True)

    key = jax.random.key(0)
    params = init_params(key, cfg)
    opt_state = opt_lib.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))

    ctx = shlib.ShardingContext(mesh) if mesh is not None else None
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"[train] {cfg.name}: {n_params/1e6:.1f}M params, "
          f"batch={dc.batch} seq={dc.seq_len}", flush=True)

    with shlib.use(ctx):
        t_start = time.time()
        for step in range(args.steps):
            batch = synth_batch(cfg, dc, step)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            if step % args.log_every == 0 or step == args.steps - 1:
                loss = float(metrics["loss"])
                print(f"[train] step {step:4d} loss={loss:8.4f} "
                      f"lr={float(metrics['lr']):.2e} "
                      f"gnorm={float(metrics['grad_norm']):.2f} "
                      f"({time.time()-t_start:.1f}s)", flush=True)
    if args.checkpoint:
        save_checkpoint(args.checkpoint, params, opt_state, args.steps)
        print(f"[train] saved {args.checkpoint}")


if __name__ == "__main__":
    main()
