"""Pallas TPU flash-decode: one query token against a deep KV cache.

The decode_32k / long_500k serving shapes are memory-bound: the whole
point of the kernel is to stream the (B, KV, S, hd) cache through VMEM
exactly once with online softmax, instead of materialising (B, H, S)
score tensors in HBM.

Two cache layouts share the same online-softmax inner loop:

``flash_decode``
    Dense per-sequence ring buffers (B, KV, S, hd).  Grid (B*KV, S/bk),
    kv blocks innermost, running (m, l, acc) in VMEM scratch like the
    prefill kernel.  All G = H/KV query heads of one KV group are
    processed together as a (G, hd) tile (G is tiny: 1-16), so the MXU
    sees a (G, hd) x (hd, bk) matmul per block.  ``length`` masks
    ring-buffer slots that are not yet populated; fully-invalid trailing
    blocks are skipped with @pl.when.

``paged_flash_decode``
    vLLM-style shared page pool (num_pages, KV, page_size, hd): every
    sequence owns a list of pages named by a per-sequence block-index
    table (B, pages_per_seq), so cache memory is pooled across requests
    instead of statically partitioned into per-slot rings.  The grid
    gains a pages dimension — (B*KV, pages_per_seq) — and the page for
    grid step (b, j) is *gathered through the block table* with a
    scalar-prefetch index map (the table is prefetched to SMEM before
    the kernel body runs, so the DMA for page j can be issued from
    ``table[b, j]``).  The dense kernel's ``length`` masking generalizes
    directly: it masks the trailing partial page, and pages past
    ceil(length/page_size) are skipped with the same @pl.when guard.
    Unmapped table entries must still be *valid* page indices (the
    caller clamps them to 0 — the allocator's reserved null page) since
    the block DMA happens regardless of the compute guard.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, scale: float, bk: int, nk: int, G: int):
    kj = pl.program_id(1)
    k_start = kj * bk
    length = len_ref[0]

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(k_start < length)
    def _compute():
        q = q_ref[0].astype(jnp.float32)              # (G, hd)
        k = k_ref[0].astype(jnp.float32)              # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (G, bk)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (G, bk), 1)
        s = jnp.where(kpos < length, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
        acc_scr[...] = (acc_scr[...] * corr
                        + jax.lax.dot_general(
                            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(kj == nk - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_decode(q, k_cache, v_cache, length, *, bk: int = 512,
                 interpret: bool = False) -> jnp.ndarray:
    """q (B, H, hd); caches (B, KV, S, hd); length () or (B,) valid tokens.

    Returns (B, H, hd).
    """
    B, H, hd = q.shape
    KV, S = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    bk = min(bk, S)
    assert S % bk == 0
    nk = S // bk
    scale = 1.0 / math.sqrt(hd)

    qf = q.reshape(B, KV, G, hd).reshape(B * KV, G, hd)
    kf = k_cache.reshape(B * KV, S, hd)
    vf = v_cache.reshape(B * KV, S, hd)
    lengths = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (B,))
    lengths = jnp.repeat(lengths, KV)                  # (B*KV,)

    kernel = functools.partial(_decode_kernel, scale=scale, bk=bk, nk=nk,
                               G=G)
    out = pl.pallas_call(
        kernel,
        grid=(B * KV, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda bh, kj: (bh,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, G, hd), lambda bh, kj: (bh, 0, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, kj: (bh, kj, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, kj: (bh, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, hd), lambda bh, kj: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(lengths, qf, kf, vf)
    return out.reshape(B, H, hd)


def _paged_decode_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                         m_scr, l_scr, acc_scr, *, scale: float, ps: int,
                         npages: int, G: int, KV: int):
    bh = pl.program_id(0)
    pj = pl.program_id(1)
    length = len_ref[bh // KV]
    k_start = pj * ps

    @pl.when(pj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(k_start < length)
    def _compute():
        q = q_ref[0].astype(jnp.float32)              # (G, hd)
        k = k_ref[0, 0].astype(jnp.float32)           # (ps, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (G, ps)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (G, ps), 1)
        s = jnp.where(kpos < length, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
        acc_scr[...] = (acc_scr[...] * corr
                        + jax.lax.dot_general(
                            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(pj == npages - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def paged_flash_decode(q, k_pages, v_pages, block_tables, lengths, *,
                       interpret: bool = False) -> jnp.ndarray:
    """Flash decode over a shared KV page pool.

    q            (B, H, hd) one query token per sequence.
    k/v_pages    (num_pages, KV, page_size, hd) the shared pool.
    block_tables (B, pages_per_seq) int32: logical page j of sequence b
                 lives in physical page ``block_tables[b, j]``.  Entries
                 past the mapped range may hold any value (clamped to a
                 valid index here; masked out of the softmax by length).
    lengths      () or (B,) valid tokens per sequence.

    Returns (B, H, hd).  A sequence with length 0 returns zeros.
    """
    B, H, hd = q.shape
    P, KV, ps, _ = k_pages.shape
    G = H // KV
    npages = block_tables.shape[1]
    scale = 1.0 / math.sqrt(hd)

    qf = q.reshape(B, KV, G, hd).reshape(B * KV, G, hd)
    tbl = jnp.clip(jnp.asarray(block_tables, jnp.int32), 0, P - 1)
    lens = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32), (B,))

    kernel = functools.partial(_paged_decode_kernel, scale=scale, ps=ps,
                               npages=npages, G=G, KV=KV)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,        # block tables + lengths land in SMEM
        grid=(B * KV, npages),
        in_specs=[
            pl.BlockSpec((1, G, hd), lambda bh, pj, tbl, lens: (bh, 0, 0)),
            pl.BlockSpec((1, 1, ps, hd),
                         lambda bh, pj, tbl, lens:
                         (tbl[bh // KV, pj], bh % KV, 0, 0)),
            pl.BlockSpec((1, 1, ps, hd),
                         lambda bh, pj, tbl, lens:
                         (tbl[bh // KV, pj], bh % KV, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, hd),
                               lambda bh, pj, tbl, lens: (bh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * KV, G, hd), q.dtype),
        interpret=interpret,
    )(tbl, lens, qf, k_pages, v_pages)
    return out.reshape(B, H, hd)
