"""Pallas TPU kernel: fused LADN reverse-diffusion chain (paper Fig. 4).

The LAD-TS scheduler evaluates an I-step reverse chain (MLP per step) for
EVERY task decision and for every (K=64)-sample training batch on every
edge server.  Naively that is I x 3 tiny matmuls with HBM round-trips
between steps; at 20-unit widths the op launch/HBM latency dominates by
orders of magnitude.

This kernel fuses the whole chain for a block of tasks:
  * weights (padded to the 128-lane width) are loaded into VMEM once and
    reused across all I steps and all task rows;
  * the state's W1 contribution (s @ W1s) is invariant across steps — it is
    computed ONCE before the unrolled step loop (an optimization the pure
    jnp reference cannot express across scan steps);
  * the I=5 steps are fully unrolled (I is a static config), so schedule
    constants (beta_i, lambda_i, ...) fold into immediates.

Layout: x (T, A), s (T, S), per-step noise (T, I, A); feature dims are
zero-padded to 128 by ops.py — zero pads are preserved by relu/matmul so
the padded lanes stay exactly 0 through the chain.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.diffusion import DiffusionSchedule


def _denoise_kernel(x_ref, s_ref, noise_ref, temb_w1_ref, w1x_ref, w1s_ref,
                    b1_ref, w2_ref, b2_ref, w3_ref, b3_ref, out_ref, *,
                    consts: Tuple[Tuple[float, float, float], ...]):
    x = x_ref[...].astype(jnp.float32)              # (bt, A)
    s = s_ref[...].astype(jnp.float32)              # (bt, S)
    w1x = w1x_ref[...]
    w2 = w2_ref[...]
    w3 = w3_ref[...]
    b1 = b1_ref[...]
    b2 = b2_ref[...]
    b3 = b3_ref[...]

    # step-invariant state contribution, computed once
    s_contrib = jax.lax.dot_general(
        s, w1s_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) + b1    # (bt, H)

    I = len(consts)  # noqa: E741
    for step in range(I):
        inv_sqrt_lam, beta_term, noise_scale = consts[step]
        t_contrib = temb_w1_ref[step]               # (H,) precomputed
        h = jax.lax.dot_general(
            x, w1x, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        h = jax.nn.relu(h + s_contrib + t_contrib[None, :])
        h = jax.nn.relu(jax.lax.dot_general(
            h, w2, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) + b2[None, :])
        eps = jax.lax.dot_general(
            h, w3, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) + b3[None, :]
        noise = noise_ref[:, step, :].astype(jnp.float32)
        x = inv_sqrt_lam * (x - beta_term * eps) + noise_scale * noise

    out_ref[...] = x.astype(out_ref.dtype)


def ladn_denoise_fused(x_I, s, noise, temb_w1, w1x, w1s, b1, w2, b2, w3,
                       b3, sched: DiffusionSchedule,
                       paper_variance: bool = True, bt: int = 128,
                       interpret: bool = False) -> jnp.ndarray:
    """Run the full reverse chain.  All feature dims must be pre-padded.

    x_I (T, A); s (T, S); noise (T, I, A); temb_w1 (I, H) = temb @ W1t.
    Returns x_0 (T, A).
    """
    T, A = x_I.shape
    S = s.shape[1]
    H = w2.shape[0]
    I = sched.num_steps  # noqa: E741
    bt = min(bt, T)
    assert T % bt == 0

    consts = []
    for step in range(I):
        i = I - step                                 # i = I..1
        idx = i - 1
        beta = float(sched.betas[idx])
        lam = float(sched.lambdas[idx])
        lbar = float(sched.lambda_bars[idx])
        btil = float(sched.beta_tildes[idx])
        scale = (btil / 2.0) if paper_variance else (btil ** 0.5)
        if i == 1:
            scale = 0.0
        consts.append((lam ** -0.5, beta / (1.0 - lbar) ** 0.5, scale))

    kernel = functools.partial(_denoise_kernel, consts=tuple(consts))
    grid = (T // bt,)
    full = lambda *shape: pl.BlockSpec(  # noqa: E731
        shape, lambda t: tuple(0 for _ in shape))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, A), lambda t: (t, 0)),
            pl.BlockSpec((bt, S), lambda t: (t, 0)),
            pl.BlockSpec((bt, I, A), lambda t: (t, 0, 0)),
            full(I, H), full(A, H), full(S, H), full(H,),
            full(H, H), full(H,), full(H, A), full(A,),
        ],
        out_specs=pl.BlockSpec((bt, A), lambda t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((T, A), jnp.float32),
        interpret=interpret,
    )(x_I, s, noise, temb_w1, w1x, w1s, b1, w2, b2, w3, b3)
