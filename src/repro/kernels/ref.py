"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.diffusion import DiffusionSchedule, reverse_step

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True,
                  window: Optional[int] = None) -> jnp.ndarray:
    """Dense masked attention.  q (B,H,Sq,hd); k,v (B,KV,Skv,hd)."""
    B, H, Sq, hd = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    G = H // KV
    kk = jnp.repeat(k, G, axis=1)
    vv = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) / jnp.sqrt(1.0 * hd)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", w, vv.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_ref(q, k_cache, v_cache, length) -> jnp.ndarray:
    """q (B,H,hd); caches (B,KV,S,hd); length () or (B,)."""
    B, H, hd = q.shape
    KV, S = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    qh = q.reshape(B, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgd,bksd->bkgs", qh, k_cache.astype(jnp.float32))
    s = s / jnp.sqrt(1.0 * hd)
    valid = (jnp.arange(S)[None, :]
             < jnp.broadcast_to(jnp.asarray(length), (B,))[:, None])
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bksd->bkgd", w, v_cache.astype(jnp.float32))
    return out.reshape(B, H, hd).astype(q.dtype)


def paged_decode_ref(q, k_pages, v_pages, block_tables, lengths
                     ) -> jnp.ndarray:
    """Paged decode oracle: gather pages dense, then :func:`decode_ref`.

    q (B,H,hd); k/v_pages (P,KV,ps,hd); block_tables (B,npages) int32;
    lengths () or (B,).  Also the XLA-compiled serving path off-TPU.
    """
    B = q.shape[0]
    P, KV, ps, hd = k_pages.shape
    npages = block_tables.shape[1]
    tbl = jnp.clip(jnp.asarray(block_tables, jnp.int32), 0, P - 1)
    k = k_pages[tbl]                              # (B, npages, KV, ps, hd)
    v = v_pages[tbl]
    k = k.transpose(0, 2, 1, 3, 4).reshape(B, KV, npages * ps, hd)
    v = v.transpose(0, 2, 1, 3, 4).reshape(B, KV, npages * ps, hd)
    return decode_ref(q, k, v, lengths)


def ladn_denoise_ref(x_I, s, noise, temb_w1, w1x, w1s, b1, w2, b2, w3, b3,
                     sched: DiffusionSchedule,
                     paper_variance: bool = True) -> jnp.ndarray:
    """Unfused reverse chain on the padded weight layout.

    Matches ladn_denoise_fused bit-for-bit op order (f32 throughout).
    noise (T, I, A): noise[:, step] is used at step = I - i.
    """
    I = sched.num_steps  # noqa: E741
    x = x_I.astype(jnp.float32)
    sf = s.astype(jnp.float32)
    s_contrib = sf @ w1s + b1
    for step in range(I):
        i = I - step
        h = jax.nn.relu(x @ w1x + s_contrib + temb_w1[step][None, :])
        h = jax.nn.relu(h @ w2 + b2[None, :])
        eps = h @ w3 + b3[None, :]
        x = reverse_step(sched, eps, x, i, noise[:, step].astype(jnp.float32),
                         paper_variance=paper_variance)
    return x
