"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True on CPU (the validation environment) and
False on TPU — the kernels are written for the TPU target (BlockSpec VMEM
tiling) and validated in interpret mode against repro.kernels.ref.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.diffusion import DiffusionSchedule
from repro.core.networks import TIME_EMBED_DIM, timestep_embed
from repro.kernels import ref as _ref
from repro.kernels.decode_attention import flash_decode as _flash_decode
from repro.kernels.decode_attention import (paged_flash_decode
                                            as _paged_flash_decode)
from repro.kernels.flash_attention import flash_attention as _flash_attn
from repro.kernels.ladn_denoise import ladn_denoise_fused

LANE = 128


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, bq: int = 512,
                    bk: int = 512,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    if interpret is None:
        interpret = _default_interpret()
    return _flash_attn(q, k, v, causal=causal, window=window, bq=bq, bk=bk,
                       interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def flash_decode(q, k_cache, v_cache, length, *, bk: int = 512,
                 interpret: Optional[bool] = None) -> jnp.ndarray:
    if interpret is None:
        interpret = _default_interpret()
    return _flash_decode(q, k_cache, v_cache, length, bk=bk,
                         interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_flash_decode(q, k_pages, v_pages, block_tables, lengths, *,
                       interpret: Optional[bool] = None) -> jnp.ndarray:
    """Paged flash decode (see kernels.decode_attention.paged_flash_decode).

    ``interpret=None`` picks the serving-sensible path per backend: the
    compiled Pallas kernel on TPU, and the XLA-compiled jnp gather oracle
    elsewhere (the Pallas *interpreter* is orders of magnitude slower than
    XLA and would dominate the engine's decode hot loop on CPU).  Pass
    ``interpret=True`` explicitly to exercise the kernel itself off-TPU
    (the validation tests do).
    """
    if interpret is None:
        if _default_interpret():
            return _ref.paged_decode_ref(q, k_pages, v_pages, block_tables,
                                         lengths)
        interpret = False
    return _paged_flash_decode(q, k_pages, v_pages, block_tables, lengths,
                               interpret=interpret)


# ---------------------------------------------------------------------------
# fused LADN chain: padding + weight-layout adapter over the kernel
# ---------------------------------------------------------------------------


def _pad_to(x, n, axis):
    pad = n - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def pack_ladn_weights(theta, state_dim: int, action_dim: int,
                      hidden: int) -> Tuple:
    """Split/pad the (A+TE+S -> H -> H -> A) MLP into the kernel layout.

    LADN input order (networks.apply_ladn): [x | time-embed | state].
    Feature dims pad to the 128-lane width.
    """
    A, TE, S = action_dim, TIME_EMBED_DIM, state_dim
    H = hidden
    w1 = theta[0]["w"]                           # (A+TE+S, H)
    w1x = w1[:A]
    w1t = w1[A:A + TE]
    w1s = w1[A + TE:]
    b1 = theta[0]["b"]
    w2, b2 = theta[1]["w"], theta[1]["b"]
    w3, b3 = theta[2]["w"], theta[2]["b"]
    Ap, Sp, Hp = LANE, LANE, LANE
    return (
        _pad_to(_pad_to(w1x, Ap, 0), Hp, 1),
        w1t,                                      # (TE, H) used host-side
        _pad_to(_pad_to(w1s, Sp, 0), Hp, 1),
        _pad_to(b1, Hp, 0),
        _pad_to(_pad_to(w2, Hp, 0), Hp, 1),
        _pad_to(b2, Hp, 0),
        _pad_to(_pad_to(w3, Hp, 0), Ap, 1),
        _pad_to(b3, Ap, 0),
    )


@functools.partial(jax.jit,
                   static_argnames=("num_steps", "paper_variance", "bt",
                                    "interpret", "state_dim", "action_dim",
                                    "hidden"))
def ladn_denoise(theta, x_I, s, key, *, num_steps: int = 5,
                 beta_min: float = 0.1, beta_max: float = 10.0,
                 paper_variance: bool = True, bt: int = 128,
                 state_dim: int, action_dim: int, hidden: int = 20,
                 interpret: Optional[bool] = None) -> Tuple[jnp.ndarray,
                                                            jnp.ndarray]:
    """Fused reverse chain for a batch of tasks.

    theta: LADN params (list of {"w","b"}); x_I (T, A); s (T, S).
    Returns (x_0 (T, A), probs (T, A)).
    """
    if interpret is None:
        interpret = _default_interpret()
    from repro.core.diffusion import make_schedule_np
    # numpy schedule: constants must be concrete at trace time so the
    # kernel can fold them into immediates
    sched = make_schedule_np(num_steps, beta_min, beta_max)
    T, A = x_I.shape
    S = s.shape[1]

    (w1x, w1t, w1s, b1, w2, b2, w3, b3) = pack_ladn_weights(
        theta, S, A, hidden)
    # per-step time contribution (I, H): computed once, tiny
    steps_i = jnp.arange(num_steps, 0, -1)        # I..1
    temb = timestep_embed(steps_i)                # (I, TE)
    temb_w1 = _pad_to(temb @ w1t, LANE, 1)        # (I, Hp)

    noise = jax.random.normal(key, (T, num_steps, A))
    Tp = ((T + bt - 1) // bt) * bt
    x_p = _pad_to(_pad_to(x_I.astype(jnp.float32), LANE, 1), Tp, 0)
    s_p = _pad_to(_pad_to(s.astype(jnp.float32), LANE, 1), Tp, 0)
    n_p = _pad_to(_pad_to(noise, LANE, 2), Tp, 0)

    x0 = ladn_denoise_fused(x_p, s_p, n_p, temb_w1, w1x, w1s, b1, w2, b2,
                            w3, b3, sched, paper_variance=paper_variance,
                            bt=bt, interpret=interpret)
    x0 = x0[:T, :A]
    return x0, jax.nn.softmax(x0, axis=-1)
