"""Pallas TPU flash attention (causal, GQA, optional sliding window).

TPU adaptation notes (vs the CUDA flash-attention algorithm):
  * The grid is (batch*q_heads, Sq/bq, Skv/bk) with the kv axis innermost —
    on TPU the grid is executed sequentially per core, so the online-softmax
    running state (m, l, acc) lives in VMEM scratch that persists across the
    kv steps of one (head, q-block); no atomics / shared-memory tiling.
  * Block shapes are (bq, head_dim) / (bk, head_dim) with head_dim padded to
    the 128-lane register width; bq=bk=512 keeps the f32 score tile
    (512 x 512 = 1 MB) + q/k/v/acc tiles well under the ~16 MB VMEM budget.
  * Fully-masked kv blocks (beyond the causal diagonal or outside the
    sliding window) are skipped with @pl.when — the compute actually
    performed matches the causal ~S^2/2 FLOPs (the pure-jnp reference twin
    in repro.models.attention computes the full rectangle and masks).

GQA: kv head index = q head index // (H // KV), folded into the BlockSpec
index maps so no repeated K/V materialisation happens.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, bq: int, bk: int, nk: int, causal: bool,
                 window: Optional[int]):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    q_start = qi * bq
    k_start = kj * bk

    # ------------------------------------------------------------------
    # block-level relevance: skip blocks fully outside the causal /
    # window region (real FLOP savings on TPU — grid steps become no-ops)
    # ------------------------------------------------------------------
    relevant = True
    if causal:
        relevant = k_start <= q_start + bq - 1
    if window is not None:
        relevant = jnp.logical_and(
            relevant, k_start + bk - 1 > q_start - window)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0].astype(jnp.float32)            # (bq, hd)
        k = k_ref[0].astype(jnp.float32)            # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(axis=1, keepdims=True)
        acc_scr[...] = (acc_scr[...] * corr
                        + jax.lax.dot_general(
                            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(kj == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, bq: int = 512,
                    bk: int = 512, interpret: bool = False) -> jnp.ndarray:
    """q (B, H, Sq, hd); k, v (B, KV, Skv, hd) -> (B, H, Sq, hd)."""
    B, H, Sq, hd = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    G = H // KV
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, bq, Skv, bk)
    nq, nk = Sq // bq, Skv // bk
    scale = 1.0 / math.sqrt(hd)

    qf = q.reshape(B * H, Sq, hd)
    kf = k.reshape(B * KV, Skv, hd)
    vf = v.reshape(B * KV, Skv, hd)

    kernel = functools.partial(_attn_kernel, scale=scale, bq=bq, bk=bk,
                               nk=nk, causal=causal, window=window)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda bh, qi, kj: (bh, qi, 0)),
            pl.BlockSpec((1, bk, hd),
                         lambda bh, qi, kj, G=G: (bh // G, kj, 0)),
            pl.BlockSpec((1, bk, hd),
                         lambda bh, qi, kj, G=G: (bh // G, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda bh, qi, kj: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # running denom
            pltpu.VMEM((bq, hd), jnp.float32),  # accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sq, hd)
