from repro.kernels.ops import (flash_attention, flash_decode, ladn_denoise,
                               paged_flash_decode)

__all__ = ["flash_attention", "flash_decode", "ladn_denoise",
           "paged_flash_decode"]
