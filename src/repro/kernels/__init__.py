from repro.kernels.ops import flash_attention, flash_decode, ladn_denoise

__all__ = ["flash_attention", "flash_decode", "ladn_denoise"]
