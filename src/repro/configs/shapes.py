"""The four assigned input shapes.

Each shape selects a *step kind*:
  * train   -> train_step   (forward+backward+optimizer)
  * prefill -> serve_prefill (forward, emit KV cache / recurrent state)
  * decode  -> serve_decode  (ONE new token against a seq_len-deep cache)
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    def __str__(self) -> str:
        return f"{self.name}(S={self.seq_len}, B={self.global_batch}, {self.kind})"


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

SHAPES: Tuple[InputShape, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def get_shape(name: str) -> InputShape:
    if name not in SHAPES_BY_NAME:
        raise KeyError(f"unknown shape {name!r}; options: {sorted(SHAPES_BY_NAME)}")
    return SHAPES_BY_NAME[name]


def smoke_shape(kind: str) -> InputShape:
    """Tiny shape of the same kind for CPU smoke tests."""
    return {
        "train": InputShape("smoke_train", 64, 2, "train"),
        "prefill": InputShape("smoke_prefill", 64, 2, "prefill"),
        "decode": InputShape("smoke_decode", 64, 2, "decode"),
    }[kind]
