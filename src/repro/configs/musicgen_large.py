"""MusicGen-Large — decoder-only over EnCodec tokens. [arXiv:2306.05284]

The EnCodec conv codec itself is a STUB: ``input_specs`` provides the 4
codebook token streams directly.  The decoder sums the per-codebook
embeddings (delay-pattern handling lives in the data pipeline) and has one
LM head per codebook — those parts are implemented for real.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    citation="arXiv:2306.05284",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,         # MHA (kv=32)
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    num_codebooks=4,
    gated_ffn=False,         # MusicGen uses plain ReLU MLPs
    use_rope=False,          # sinusoidal positions, not rotary
    pattern=(("attn", "dense"),),
    # MHA (kv=32) makes the decode_32k cache the largest per-token state of
    # any assigned arch (19.8 GB/chip in bf16 — over the 16 GB HBM budget);
    # int8 cache storage brings it to 3.8 GB at ~1% logit error (§Perf H2).
    kv_cache_dtype="int8",
    long_context_window=8192,
)
