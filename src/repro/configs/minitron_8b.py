"""Minitron-8B — width/depth-pruned Nemotron-4. [arXiv:2407.14679]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    citation="arXiv:2407.14679",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,          # GQA kv=8
    head_dim=128,
    d_ff=16384,
    vocab_size=256000,
    gated_ffn=False,         # Nemotron uses squared-ReLU, non-gated MLP
    pattern=(("attn", "dense"),),
    long_context_window=8192,
)
