"""RecurrentGemma-9B — Griffin hybrid: RG-LRU recurrent blocks interleaved
with local (windowed) attention at a 1:2 attention:recurrent ratio.
[arXiv:2402.19427]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    citation="arXiv:2402.19427",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,          # MQA kv=1
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    gated_ffn=True,
    rg_lru_dim=4096,
    local_window=2048,       # local attention window
    # (recurrent, recurrent, local-attn) repeating — 1:2 attn:recurrent
    pattern=(("rglru", "dense"), ("rglru", "dense"), ("attn", "dense")),
)
