"""LLaVA-NeXT (Mistral-7B backbone) — VLM with anyres tiling.
[hf:llava-hf/llava-v1.6-mistral-7b-hf]

The SigLIP/CLIP vision tower is a STUB: ``input_specs`` provides precomputed
patch embeddings of shape (batch, patches, vision_dim).  The multimodal
projector (2-layer MLP) and the Mistral decoder are implemented for real;
anyres tiling determines ``vision_patches`` (here the 576-patch base tile).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    citation="hf:llava-hf/llava-v1.6-mistral-7b-hf",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,          # GQA kv=8 (Mistral)
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    gated_ffn=True,          # SwiGLU
    vision_patches=576,      # 24x24 base-resolution tile (anyres base)
    vision_dim=1024,         # CLIP ViT-L/14 feature width
    pattern=(("attn", "dense"),),
    long_context_window=8192,
)
