"""xLSTM-350M — alternating sLSTM + mLSTM blocks. [arXiv:2405.04517]

d_ff=0: xLSTM blocks carry their own up/down projections (ffn="none").
Fully recurrent -> sub-quadratic decode, long_500k in-family.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    citation="arXiv:2405.04517",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    head_dim=256,
    d_ff=0,
    vocab_size=50304,
    # xLSTM[7:1]-style interleave: mostly mLSTM with periodic sLSTM blocks.
    pattern=(("mlstm", "none"), ("mlstm", "none"), ("mlstm", "none"),
             ("slstm", "none")),
)
