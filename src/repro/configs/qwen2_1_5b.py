"""Qwen2-1.5B — dense GQA with QKV bias. [arXiv:2407.10671]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b",
    family="dense",
    citation="arXiv:2407.10671",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,          # GQA kv=2
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    qkv_bias=True,           # Qwen2 signature: bias on QKV projections
    gated_ffn=True,
    tie_embeddings=True,     # Qwen2-1.5B ties embed/lm_head
    pattern=(("attn", "dense"),),
    long_context_window=8192,
)
