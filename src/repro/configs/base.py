"""Configuration system for the DEdgeAI/LAD-TS reproduction framework.

Every servable architecture is described by a :class:`ModelConfig`.  A config
is a *pure data* object — models are built from it functionally (no global
registry side effects).  Reduced variants (for CPU smoke tests) are derived
with :func:`reduced`, keeping the family-specific structure (MoE, SSM, hybrid
patterns, GQA ratios) while shrinking dimensions.

Block kinds
-----------
The unified decoder is a stack of blocks.  Each block has
  * a *mixer*  : how tokens exchange information
      - "attn"    : GQA multi-head attention (full causal, optionally RoPE,
                    optionally sliding-window / local)
      - "mlstm"   : xLSTM matrix-memory cell (linear-attention style)
      - "slstm"   : xLSTM scalar-memory cell
      - "rglru"   : RecurrentGemma real-gated linear recurrent unit
  * a *ffn*    : "dense" (optionally gated/SwiGLU), "moe", or "none"
                 (xLSTM blocks carry their own projections).

``layer_pattern()`` expands the per-arch pattern into ``num_layers`` block
specs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Block-level description
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    """One decoder block: a sequence mixer plus a feed-forward."""

    mixer: str = "attn"          # attn | mlstm | slstm | rglru
    ffn: str = "dense"           # dense | moe | none
    window: Optional[int] = None  # sliding/local attention window (tokens)
    use_rope: bool = True        # rotary embeddings (attn only)

    def is_recurrent(self) -> bool:
        return self.mixer in ("mlstm", "slstm", "rglru")


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01  # load-balance auxiliary loss weight


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # identity --------------------------------------------------------------
    name: str = "model"
    family: str = "dense"        # dense | moe | ssm | hybrid | vlm | audio
    citation: str = ""

    # transformer dimensions --------------------------------------------------
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0            # 0 -> d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 1024

    # attention behaviour -----------------------------------------------------
    qkv_bias: bool = False       # Qwen2-style bias on QKV projections
    use_rope: bool = True        # rotary embeddings (False -> sinusoidal adds)
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None      # arch-native SWA (Mixtral)
    local_window: Optional[int] = None        # local-attn window (RG hybrid)
    long_context_window: Optional[int] = None  # beyond-paper SW variant used
    # only for the long_500k serving shape on otherwise-full-attention archs.

    # layer pattern -----------------------------------------------------------
    # pattern of block templates, tiled to num_layers.  Encoded as a tuple of
    # (mixer, ffn) pairs; window defaults resolved in layer_pattern().
    pattern: Tuple[Tuple[str, str], ...] = (("attn", "dense"),)

    # ffn behaviour -----------------------------------------------------------
    gated_ffn: bool = True       # SwiGLU-style gated MLP
    moe: Optional[MoEConfig] = None

    # recurrent dims (ssm / hybrid) -------------------------------------------
    rg_lru_dim: int = 0          # RG-LRU recurrence width (0 -> d_model)
    conv1d_width: int = 4        # temporal conv in recurrent blocks

    # modality frontend stubs ---------------------------------------------------
    # [vlm]: number of image patch embeddings prepended per sample and the
    # (stub) vision encoder output dim fed through the (real) projector.
    vision_patches: int = 0
    vision_dim: int = 0
    # [audio]: number of EnCodec codebooks (MusicGen sums their embeddings and
    # has one LM head per codebook).
    num_codebooks: int = 0

    # numerics / training ------------------------------------------------------
    dtype: str = "bfloat16"
    # KV cache storage: "model" (= dtype) or "int8" (per-slot symmetric
    # quantization; beyond-paper serving optimization, §Perf)
    kv_cache_dtype: str = "model"
    # chunk size of the online-softmax attention (VMEM-tile twin); smaller
    # chunks shrink the transient (Cq x Ckv) f32 score buffers
    attn_chunk: int = 1024
    # RG-LRU recurrence evaluation: sequential lax.scan (Griffin's TPU
    # reference behaviour) vs parallel lax.associative_scan (§Perf)
    use_assoc_scan: bool = False
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # scan over layers keeps the HLO size O(1) in depth — essential for the
    # 512-partition dry-run compiles on a single CPU core.
    scan_layers: bool = True
    remat: bool = True           # activation checkpointing in train_step
    # gradient-accumulation microbatches per train step.  Bounds the live
    # remat residual stack (L x B/k x S x d) that dominates training HBM.
    microbatches: int = 8
    # Hoist the MoE expert-weight re-layout (E,d,f) -> (M,r,d,f_lp) out of
    # the layer x microbatch loops: transform params once per step and
    # inverse-transform the accumulated grads (beyond-paper, §Perf).
    hoist_moe_layout: bool = False
    # Weights-stationary serving MoE (beyond-paper, §Perf): when the token
    # count is tiny (decode), all-gather the TOKENS across the data axis
    # and keep expert weights fully sharded (expert on 'model', d on
    # 'data') instead of re-gathering GBs of weights per decode step.
    moe_stationary_serve: bool = False
    moe_stationary_max_tokens: int = 4096

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.num_heads % self.num_kv_heads == 0, (
            f"{self.name}: q heads {self.num_heads} not a multiple of kv "
            f"heads {self.num_kv_heads}")

    # ------------------------------------------------------------------
    def layer_pattern(self) -> Tuple[BlockSpec, ...]:
        """Expand ``pattern`` to ``num_layers`` BlockSpecs."""
        blocks = []
        for i in range(self.num_layers):
            mixer, ffn = self.pattern[i % len(self.pattern)]
            window = None
            if mixer == "attn":
                if self.family == "hybrid" and len(self.pattern) > 1:
                    window = self.local_window
                elif self.sliding_window is not None:
                    window = self.sliding_window
            blocks.append(BlockSpec(mixer=mixer, ffn=ffn, window=window,
                                    use_rope=self.use_rope))
        return tuple(blocks)

    # ------------------------------------------------------------------
    @property
    def uniform_blocks(self) -> bool:
        """True when every layer has an identical BlockSpec (scan-friendly)."""
        pat = self.layer_pattern()
        return all(b == pat[0] for b in pat)

    def is_subquadratic(self) -> bool:
        """Can this arch serve the 500k-token decode shape?

        True when every block is recurrent or windowed attention, OR when a
        beyond-paper ``long_context_window`` has been configured.
        """
        if self.long_context_window is not None:
            return True
        for b in self.layer_pattern():
            if b.mixer == "attn" and b.window is None:
                return False
        return True

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (used in roofline MODEL_FLOPS)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim
        n = 0
        n += v * d                                   # token embedding
        if not self.tie_embeddings:
            n += d * v                               # lm head
        if self.num_codebooks:
            n += (self.num_codebooks - 1) * v * d    # extra codebook embeds
            n += (self.num_codebooks - 1) * d * v    # extra heads
        if self.vision_patches:
            n += self.vision_dim * d + d * d         # projector MLP
        for blk in self.layer_pattern():
            if blk.mixer == "attn":
                n += d * (self.num_heads * hd)       # wq
                n += 2 * d * (self.num_kv_heads * hd)  # wk, wv
                n += (self.num_heads * hd) * d       # wo
            elif blk.mixer == "mlstm":
                n += 3 * d * (self.num_heads * hd) + 2 * d * self.num_heads
                n += (self.num_heads * hd) * d
                n += 2 * d * 2 * d                   # up/down proj (ffn=none)
            elif blk.mixer == "slstm":
                n += 4 * d * d + 4 * d * d           # input + recurrent gates
            elif blk.mixer == "rglru":
                rd = self.rg_lru_dim or d
                n += d * rd * 2 + rd * d + 2 * rd * rd // 8  # in/gate/out + lru
            if blk.ffn == "dense":
                mult = 3 if self.gated_ffn else 2
                n += mult * d * f
            elif blk.ffn == "moe":
                mult = 3 if self.gated_ffn else 2
                n += self.moe.num_experts * mult * d * f
                n += d * self.moe.num_experts        # router
            n += 2 * d                               # 2 norms
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE uses top_k of num_experts)."""
        if self.moe is None:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        mult = 3 if self.gated_ffn else 2
        dense_like = self.param_count()
        n_moe_blocks = sum(1 for b in self.layer_pattern() if b.ffn == "moe")
        inactive = n_moe_blocks * (self.moe.num_experts - self.moe.top_k) * mult * d * f
        return dense_like - inactive


def reduced(cfg: ModelConfig, *, num_layers: int = 2, d_model: int = 256,
            max_experts: int = 4) -> ModelConfig:
    """Shrink a config to a CPU-smoke-test variant of the same family."""
    heads = max(2, min(cfg.num_heads, 4))
    kv = max(1, min(cfg.num_kv_heads, heads))
    while heads % kv:
        kv -= 1
    moe = None
    if cfg.moe is not None:
        moe = MoEConfig(num_experts=min(cfg.moe.num_experts, max_experts),
                        top_k=min(cfg.moe.top_k, 2),
                        capacity_factor=cfg.moe.capacity_factor)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=num_layers,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=d_model // heads,
        d_ff=max(4 * d_model // 2, 128) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        vision_dim=min(cfg.vision_dim, 128) if cfg.vision_dim else 0,
        vision_patches=min(cfg.vision_patches, 16) if cfg.vision_patches else 0,
        rg_lru_dim=d_model if cfg.rg_lru_dim else 0,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else None,
        local_window=min(cfg.local_window, 64) if cfg.local_window else None,
        long_context_window=(min(cfg.long_context_window, 64)
                             if cfg.long_context_window else None),
        moe=moe,
        scan_layers=False,
        microbatches=1,
        dtype="float32",
    )
