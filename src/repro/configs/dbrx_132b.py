"""DBRX-132B — fine-grained MoE, 16 experts top-4. [hf:databricks/dbrx-base]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    citation="hf:databricks/dbrx-base",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,          # GQA kv=8
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    gated_ffn=True,
    moe=MoEConfig(num_experts=16, top_k=4, capacity_factor=1.25),
    pattern=(("attn", "moe"),),
    microbatches=16,   # d_model=6144: halve the remat residual stack
    # decode shapes: never re-gather expert weights per token — gather the
    # tiny token batch instead (weights-stationary serving MoE, §Perf H1:
    # 15x less collective traffic on decode_32k)
    moe_stationary_serve=True,
    # full attention: long_500k served via the beyond-paper SW variant
    long_context_window=8192,
)
