"""StarCoder2-3B — dense GQA + RoPE. [arXiv:2402.19173]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    citation="arXiv:2402.19173",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,          # GQA kv=2
    head_dim=128,
    d_ff=12288,
    vocab_size=49152,
    gated_ffn=False,         # StarCoder2 uses a plain (non-gated) MLP
    pattern=(("attn", "dense"),),
    # StarCoder2 natively interleaves 4k sliding-window attention; we use the
    # window for the long_500k serving shape.
    long_context_window=4096,
)
