"""Mixtral-8x22B — 8-expert top-2 MoE with sliding-window attention.
[arXiv:2401.04088]
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    citation="arXiv:2401.04088",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,          # GQA kv=8
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    gated_ffn=True,          # SwiGLU experts
    moe=MoEConfig(num_experts=8, top_k=2, capacity_factor=1.25),
    pattern=(("attn", "moe"),),
    microbatches=16,   # d_model=6144: halve the remat residual stack
    # decode shapes: never re-gather expert weights per token — gather the
    # tiny token batch instead (weights-stationary serving MoE, §Perf H1:
    # 15x less collective traffic on decode_32k)
    moe_stationary_serve=True,
    attn_chunk=512,    # shrink transient attention score tiles (§Perf H3)
    sliding_window=4096,     # native SWA -> long_500k is in-family
)
