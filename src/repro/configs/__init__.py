"""Architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import BlockSpec, ModelConfig, MoEConfig, reduced
from repro.configs.shapes import (SHAPES, SHAPES_BY_NAME, InputShape,
                                  get_shape, smoke_shape)

# arch-id -> module path (one module per assigned architecture)
_ARCH_MODULES: Dict[str, str] = {
    "dbrx-132b": "repro.configs.dbrx_132b",
    "starcoder2-3b": "repro.configs.starcoder2_3b",
    "musicgen-large": "repro.configs.musicgen_large",
    "minitron-8b": "repro.configs.minitron_8b",
    "starcoder2-7b": "repro.configs.starcoder2_7b",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "xlstm-350m": "repro.configs.xlstm_350m",
    "recurrentgemma-9b": "repro.configs.recurrentgemma_9b",
    "llava-next-mistral-7b": "repro.configs.llava_next_mistral_7b",
    "qwen2-1.5b": "repro.configs.qwen2_1_5b",
}

ARCH_IDS: List[str] = list(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; options: {ARCH_IDS}")
    return importlib.import_module(_ARCH_MODULES[arch]).CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


__all__ = [
    "ARCH_IDS", "BlockSpec", "InputShape", "ModelConfig", "MoEConfig",
    "SHAPES", "SHAPES_BY_NAME", "all_configs", "get_config", "get_shape",
    "reduced", "smoke_shape",
]
