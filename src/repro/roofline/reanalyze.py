"""Re-derive roofline records from saved HLO (no recompilation).

  PYTHONPATH=src python -m repro.roofline.reanalyze \
      --hlo-dir results/hlo --out results/dryrun_16x16.jsonl
"""
from __future__ import annotations

import argparse
import glob
import gzip
import json
import os

from repro.configs import get_config, get_shape
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.models.transformer import config_for_shape
from repro.roofline import analysis as ra


def reanalyze_file(path: str) -> dict:
    name = os.path.basename(path).replace(".hlo.gz", "")
    arch, shape_name, mesh_tag = name.split("__")
    cfg = config_for_shape(get_config(arch), get_shape(shape_name))
    shape = get_shape(shape_name)
    chips = 1
    for d in mesh_tag.split("x"):
        chips *= int(d)
    with gzip.open(path, "rt") as f:
        hlo = f.read()
    colls = ra.collect_collectives(hlo)
    coll_bytes = sum(c.scaled_bytes for c in colls)
    coll_by_kind = {}
    for c in colls:
        coll_by_kind[c.kind] = coll_by_kind.get(c.kind, 0) + c.scaled_bytes
    flops, bytes_, _ = ra.scaled_cost(hlo, 0.0, 0.0)
    mflops = ra.model_flops(cfg, shape)
    terms = ra.roofline_terms(flops, bytes_, coll_bytes, chips,
                              PEAK_FLOPS_BF16, HBM_BW, ICI_BW)
    return {
        "arch": arch, "shape": shape_name, "mesh": mesh_tag,
        "chips": chips, "hlo_flops": flops, "hlo_bytes": bytes_,
        "collective_bytes": coll_bytes,
        "collective_by_kind": coll_by_kind,
        "n_collectives": len(colls), "model_flops": mflops,
        "useful_ratio": (mflops / chips / flops) if flops else None,
        **terms,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--hlo-dir", default="results/hlo")
    ap.add_argument("--out", default=None)
    ap.add_argument("--mesh", default=None, help="filter, e.g. 16x16")
    args = ap.parse_args()
    recs = []
    for path in sorted(glob.glob(os.path.join(args.hlo_dir, "*.hlo.gz"))):
        if args.mesh and not path.endswith(f"__{args.mesh}.hlo.gz"):
            continue
        rec = reanalyze_file(path)
        recs.append(rec)
        print(f"{rec['arch']:24s} {rec['shape']:12s} {rec['mesh']:8s} "
              f"{rec['bottleneck']:10s} c={rec['compute_s']:.2e} "
              f"m={rec['memory_s']:.2e} x={rec['collective_s']:.2e}")
    if args.out:
        with open(args.out, "w") as f:
            for rec in recs:
                f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()
