"""Roofline analysis from the compiled dry-run artifact.

Three terms per (arch x shape x mesh), in seconds:

    compute    = HLO_FLOPs      / (chips * PEAK_FLOPS_BF16)
    memory     = HLO_bytes      / (chips * HBM_BW)
    collective = coll_bytes     / (chips * ICI_BW)

Sources:
  * ``compiled.cost_analysis()`` -> flops / bytes accessed.  XLA counts a
    while-loop body ONCE, so scan-over-layers (and the chunked-attention /
    chunked-CE scans) would undercount by the trip count: we parse the
    optimized HLO, attribute ops to their enclosing computation, recover
    each while loop's trip count from its induction-variable bound, and
    scale.
  * collective bytes are NOT in cost_analysis: we sum operand sizes of
    every all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute in the optimized HLO (same loop scaling).
  * MODEL_FLOPS = 6*N*D (train) or 2*N*D (inference), N = active params —
    the "useful compute" yardstick; HLO/MODEL ratio surfaces remat or
    dispatch waste.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[16,128]{1,0}' or tuple '(f32[2], s32[])' -> total bytes."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    bytes: int
    computation: str
    scaled_bytes: int = 0


_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{\s*$")


def split_computations(hlo: str) -> Tuple[Dict[str, List[str]], str]:
    """Optimized-HLO text -> ({computation_name: [op lines]}, entry)."""
    comps: Dict[str, List[str]] = {}
    cur = None
    entry = ""
    for line in hlo.splitlines():
        s = line.rstrip()
        m = _HDR_RE.match(s.strip())
        if m:
            cur = m.group(2)
            comps[cur] = []
            if m.group(1):
                entry = cur
        elif cur is not None and s.strip() == "}":
            cur = None
        elif cur is not None:
            comps[cur].append(s.strip())
    return comps, entry


def _extract_bound(cond_lines: List[str]) -> Optional[int]:
    consts = []
    for ln in cond_lines:
        m = re.search(r"constant\((\d+)\)", ln)
        if m:
            consts.append(int(m.group(1)))
    if not consts:
        return None  # bound flows in as a parameter (dynamic): unknown
    return max(consts)  # jax scans compare i < N; N is the largest constant


_CALL_RE = re.compile(
    r"(?:condition=|body=|calls=|to_apply=)%?([\w\.\-]+)")
_WHILE_RE = re.compile(
    r"while\(.*?condition=%?([\w\.\-]+).*?body=%?([\w\.\-]+)", re.S)


def computation_multipliers(hlo: str) -> Tuple[Dict[str, float],
                                               Dict[str, List[str]],
                                               Dict[str, bool]]:
    """Execution-count multiplier per computation, plus a "control" flag.

    Nesting-aware: a while body executes trip(cond) times per execution of
    its enclosing computation; fusions / wrapped computations inherit their
    caller's multiplier.  Unknown trip counts count as 1 (conservative).

    ``control[comp]`` is True for the entry and loop bodies/conditions —
    the computations whose op outputs are real HBM buffers (fusion
    internals never touch HBM).
    """
    comps, entry = split_computations(hlo)
    # build call edges: comp -> [(callee, factor)]
    edges: Dict[str, List[Tuple[str, float]]] = {c: [] for c in comps}
    control: Dict[str, bool] = {c: False for c in comps}
    if entry in comps:
        control[entry] = True
    for comp, lines in comps.items():
        for ln in lines:
            if "while(" in ln:
                mb = re.search(r"body=%?([\w\.\-]+)", ln)
                mc = re.search(r"condition=%?([\w\.\-]+)", ln)
                if mb and mc:
                    trip = _extract_bound(comps.get(mc.group(1), [])) or 1
                    edges[comp].append((mb.group(1), float(trip)))
                    edges[comp].append((mc.group(1), float(trip)))
                    control[mb.group(1)] = True
                    control[mc.group(1)] = True
                    continue
            if "conditional(" in ln:
                for m in _CALL_RE.finditer(ln):
                    if m.group(1) in comps:
                        edges[comp].append((m.group(1), 1.0))
                        control[m.group(1)] = True
                continue
            for m in _CALL_RE.finditer(ln):
                callee = m.group(1)
                if callee in comps and "while(" not in ln:
                    edges[comp].append((callee, 1.0))

    mult: Dict[str, float] = {c: 0.0 for c in comps}
    if entry not in comps:
        entry = max(comps, key=lambda c: len(comps[c])) if comps else ""
        control[entry] = True
    if entry:
        mult[entry] = 1.0
        # propagate in topological-ish order via repeated relaxation
        for _ in range(24):
            changed = False
            for comp, outs in edges.items():
                base = mult.get(comp, 0.0)
                if base == 0.0:
                    continue
                for callee, factor in outs:
                    add = base * factor
                    if mult.get(callee, 0.0) < add:
                        mult[callee] = add
                        changed = True
            if not changed:
                break
    for c in comps:
        mult.setdefault(c, 1.0)
        if mult[c] == 0.0:
            mult[c] = 1.0  # unreached (e.g. dead comp): count once
    return mult, comps, control


def collect_collectives(hlo: str) -> List[CollectiveOp]:
    mult, comps, _ = computation_multipliers(hlo)
    out: List[CollectiveOp] = []
    for comp, lines in comps.items():
        m = mult.get(comp, 1.0)
        for ln in lines:
            for kind in _COLLECTIVES:
                idx = ln.find(f" {kind}(")
                if idx < 0 or "=" not in ln[:idx]:
                    continue
                # output shape(s): between '=' and the op mnemonic
                seg = ln[ln.index("=") + 1:idx]
                b = _shape_bytes(seg)
                if b == 0:  # odd formatting: whole-line fallback
                    b = _shape_bytes(ln)
                out.append(CollectiveOp(kind=kind, bytes=b,
                                        computation=comp,
                                        scaled_bytes=int(b * m)))
                break
    return out


def scaled_cost(hlo: str, raw_flops: float, raw_bytes: float
                ) -> Tuple[float, float, float]:
    """Scale cost_analysis totals by while trip counts.

    XLA counts each while body once; we re-estimate FLOPs per computation
    from dot shapes scaled by the nesting-aware execution multipliers.
    Returns (flops_scaled, bytes_scaled, dot_flops_unscaled).
    """
    mult, comps, control = computation_multipliers(hlo)
    total = 0.0
    unscaled = 0.0
    bytes_total = 0.0
    for comp, lines in comps.items():
        m = mult.get(comp, 1.0)
        f = _comp_dot_flops(lines)
        total += f * m
        unscaled += f
        if control.get(comp):
            # HBM traffic proxy: outputs of materializing top-level ops
            # (fusion internals never hit HBM; bitcasts / tuples /
            # parameters are views).  Reads are other ops' writes, so
            # outputs are counted once.
            b = 0.0
            b_once = 0.0
            for ln in lines:
                dm = _DEF_RE.match(ln)
                if not dm:
                    continue
                if not _MATERIALIZING_RE.search(ln):
                    continue
                sz = _shape_bytes(dm.group(2))
                if "dynamic_update_slice" in ln or \
                        "dynamic-update-slice" in ln:
                    # in-place slice write: the full buffer is written once
                    # across the whole loop, not once per iteration
                    b_once += sz
                else:
                    b += sz
            bytes_total += b * m + b_once
    bytes_scaled = max(bytes_total, raw_bytes)
    return total, bytes_scaled, unscaled


_MATERIALIZING_RE = re.compile(
    r"\b(fusion|dot|convolution|copy|dynamic-slice|dynamic-update-slice|"
    r"all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute|"
    r"scatter|gather|reduce|sort|rng|iota|broadcast|transpose|reshape|"
    r"convert|select|add|multiply|concatenate|pad|slice)\(")


_DEF_RE = re.compile(r"^%?([\w\.\-]+)\s*=\s*(\S+\[[\d,]*\])")
_DOT_OPS_RE = re.compile(r"\bdot\(([^)]*)\)")


def _comp_dot_flops(lines: List[str]) -> float:
    """2 * M*N*K per dot, resolving operand shapes through the
    computation's instruction definitions."""
    defs: Dict[str, List[int]] = {}
    for ln in lines:
        m = _DEF_RE.match(ln)
        if m:
            sm = _SHAPE_RE.search(m.group(2))
            if sm:
                defs[m.group(1)] = [int(d) for d in sm.group(2).split(",")
                                    if d]
    total = 0.0
    for ln in lines:
        if " dot(" not in ln or "=" not in ln:
            continue
        m = _DEF_RE.match(ln)
        if not m:
            continue
        out_dims = defs.get(m.group(1), [])
        out_elems = 1
        for d in out_dims:
            out_elems *= d
        k = 1
        mo = _DOT_OPS_RE.search(ln)
        mk = re.search(r"lhs_contracting_dims=\{([\d,]+)\}", ln)
        if mo and mk:
            first = mo.group(1).split(",")[0].strip().lstrip("%")
            dims0 = defs.get(first, [])
            for idx in (int(i) for i in mk.group(1).split(",") if i):
                if idx < len(dims0):
                    k *= dims0[idx]
        total += 2.0 * out_elems * k
    return total


# ---------------------------------------------------------------------------
# analytic MODEL_FLOPS
# ---------------------------------------------------------------------------


def model_flops(cfg, shape) -> float:
    """6*N_active*D for training, 2*N_active*D for inference."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def roofline_terms(flops: float, bytes_: float, coll_bytes: float,
                   chips: int, peak_flops: float, hbm_bw: float,
                   ici_bw: float) -> Dict[str, float]:
    """Three roofline terms in seconds.

    The compiled SPMD module is PER-DEVICE (cost_analysis numbers and the
    HLO operand shapes are the per-chip shards), so each term divides by a
    single chip's capability; ``chips`` normalises the formula-style
    "global work / (chips x capability)" identically.
    """
    compute = flops / peak_flops
    memory = bytes_ / hbm_bw
    collective = coll_bytes / ici_bw
    dom = max(("compute", compute), ("memory", memory),
              ("collective", collective), key=lambda kv: kv[1])
    return {
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "bottleneck": dom[0],
    }
