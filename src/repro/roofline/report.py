"""Render EXPERIMENTS.md tables from the dry-run JSONL artifacts.

  PYTHONPATH=src python -m repro.roofline.report [--results results/]
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Optional


def load(path: str) -> List[dict]:
    if not os.path.exists(path):
        return []
    recs = []
    with open(path) as f:
        for line in f:
            try:
                recs.append(json.loads(line))
            except json.JSONDecodeError:
                pass
    return recs


def latest_by_combo(recs: List[dict], tag: Optional[str] = None
                    ) -> Dict[tuple, dict]:
    out = {}
    for r in recs:
        if "bottleneck" not in r:
            continue
        if tag is not None and r.get("tag") != tag:
            continue
        out[(r["arch"], r["shape"])] = r
    return out


def fmt_bytes(b) -> str:
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def dryrun_table(recs: Dict[tuple, dict]) -> str:
    lines = ["| arch | shape | compile | HBM/dev (args+temp) | "
             "collectives (count) |",
             "|---|---|---|---|---|"]
    for (arch, shape), r in sorted(recs.items()):
        mem = r.get("memory_analysis", {})
        args_b = mem.get("argument_size_in_bytes")
        temp_b = mem.get("temp_size_in_bytes")
        tot = (args_b or 0) + (temp_b or 0)
        lines.append(
            f"| {arch} | {shape} | {r.get('compile_s', '?')}s "
            f"| {fmt_bytes(tot)} ({fmt_bytes(args_b)}+{fmt_bytes(temp_b)}) "
            f"| {fmt_bytes(r.get('collective_bytes'))} "
            f"({r.get('n_collectives', '?')}) |")
    return "\n".join(lines)


def roofline_table(recs: Dict[tuple, dict]) -> str:
    lines = ["| arch | shape | compute (s) | memory (s) | collective (s) "
             "| bottleneck | useful |",
             "|---|---|---|---|---|---|---|"]
    for (arch, shape), r in sorted(recs.items()):
        u = r.get("useful_ratio")
        lines.append(
            f"| {arch} | {shape} | {r['compute_s']:.2e} "
            f"| {r['memory_s']:.2e} | {r['collective_s']:.2e} "
            f"| **{r['bottleneck']}** "
            f"| {'-' if u is None else f'{u:.2f}'} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="results")
    ap.add_argument("--tag", default=None)
    args = ap.parse_args()
    for fname, title in (("dryrun_16x16.jsonl", "16x16 (256 chips)"),
                         ("dryrun_2x16x16.jsonl",
                          "2x16x16 (512 chips, multi-pod)")):
        recs = latest_by_combo(load(os.path.join(args.results, fname)),
                               args.tag)
        print(f"\n### Dry-run — {title}: {len(recs)} combos\n")
        print(dryrun_table(recs))
        print(f"\n### Roofline — {title}\n")
        print(roofline_table(recs))


if __name__ == "__main__":
    main()
